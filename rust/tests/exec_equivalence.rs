//! Executor equivalence (ISSUE 3, extended by ISSUE 6): `SimExecutor`,
//! `ThreadedExecutor`, and `ProcessExecutor` must be interchangeable —
//! bit-identical gradients, identical `vjp_units`/`calls`, and a
//! consistent `BackwardPlan` — across seeds, scheduling policies
//! (fifo | lpt | layer-major), `--overlap` on/off, fleet sizes, worker
//! caps, and batched dispatch widths.
//!
//! Host-side tests (dispatch-contract invariants) run everywhere; the
//! PJRT equivalence sweep skips with a message when `make artifacts`
//! hasn't run.

use std::path::{Path, PathBuf};

use adjoint_sharding::adjoint::{self, StagePool};
use adjoint_sharding::config::{ModelDims, SchedCfg, TopologyCfg};
use adjoint_sharding::data::{Corpus, MarkovCorpus};
use adjoint_sharding::exec::{
    plan_dispatch, Executor, ProcessExecutor, SimExecutor, ThreadedExecutor,
};
use adjoint_sharding::model::{GradSet, ParamSet};
use adjoint_sharding::obs::trace::span_multiset;
use adjoint_sharding::pipeline;
use adjoint_sharding::runtime::{ArtifactSet, Runtime};
use adjoint_sharding::schedule::{BackwardPlan, DeviceSchedule, PolicyKind};
use adjoint_sharding::sharding::plan_chunks;
use adjoint_sharding::topology::Fleet;

// ---------------------------------------------------------------------------
// Host-side: dispatch-contract invariants (no artifacts needed).
// ---------------------------------------------------------------------------

/// Max number of spans simultaneously in flight on one device's timeline.
fn max_concurrency(d: &DeviceSchedule) -> usize {
    d.spans
        .iter()
        .map(|s| {
            d.spans
                .iter()
                .filter(|o| o.start_s < s.end_s - 1e-12 && o.end_s > s.start_s + 1e-12)
                .count()
        })
        .max()
        .unwrap_or(0)
}

fn plan_respects_slot_caps(plan: &BackwardPlan, slots: usize) {
    for d in &plan.schedule.devices {
        assert!(
            max_concurrency(d) <= slots,
            "device {} exceeded its {slots} MIG slots",
            d.device
        );
    }
}

#[test]
fn dispatch_contract_invariants_across_seeds_and_policies() {
    for seed in [0u64, 9, 77] {
        for devices in [1usize, 2, 3] {
            for policy in PolicyKind::ALL {
                let dims = ModelDims {
                    name: "exec".into(),
                    v: 16,
                    p: 8,
                    n: 6,
                    k: 3 + (seed as usize % 3),
                    t: 32,
                    w: 8,
                    c: 8,
                    eps: 1e-6,
                };
                if devices > dims.k {
                    continue;
                }
                let topo = TopologyCfg { devices, mig_slots: 2, ..Default::default() };
                let fleet = Fleet::new(topo, dims.k).unwrap();
                let items = plan_chunks(dims.k, dims.t, dims.c).unwrap();
                let sched = SchedCfg { policy, overlap: false, ..Default::default() };
                let caps: Vec<Option<u64>> = vec![Some(1 << 20); devices];
                let d = plan_dispatch(&dims, &fleet, &items, &sched, 4096, &caps, 1).unwrap();

                // Every item scheduled exactly once, on its owner, queues
                // ascending (the pinned reduction order).
                let mut seen = vec![false; items.len()];
                for (dev, q) in d.queues.iter().enumerate() {
                    assert!(q.windows(2).all(|w| w[0] < w[1]));
                    for &id in q {
                        assert!(!seen[id], "item {id} scheduled twice");
                        seen[id] = true;
                        assert_eq!(fleet.device_of_layer(items[id].layer), dev);
                    }
                }
                assert!(seen.iter().all(|&s| s));
                assert_eq!(d.plan.schedule.scheduled_items(), items.len());
                plan_respects_slot_caps(&d.plan, 2);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT: sim ≡ threaded, bit for bit. Skips without artifacts.
// ---------------------------------------------------------------------------

fn root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have(name: &str) -> bool {
    root().join(name).join("manifest.json").exists()
}

/// A process executor whose child workers re-exec the adjsh binary cargo
/// built for this test run.
fn process_executor(workers: usize) -> ProcessExecutor {
    ProcessExecutor::new(workers).with_program(PathBuf::from(env!("CARGO_BIN_EXE_adjsh")))
}

fn assert_grads_bit_identical(a: &GradSet, b: &GradSet, ctx: &str) {
    for (k, (la, lb)) in a.layers.iter().zip(&b.layers).enumerate() {
        for (i, (ta, tb)) in la.0.iter().zip(&lb.0).enumerate() {
            assert_eq!(
                ta.data(),
                tb.data(),
                "{ctx}: layer {k} grad {i} differs between executors"
            );
        }
    }
    assert_eq!(a.omega.data(), b.omega.data(), "{ctx}: dΩ differs");
}

/// One forward, then the same backward phase under both executors against
/// the same activations — the isolation that makes bit-equality a fair
/// (and required) assertion.
fn compare_backends(
    config: &str,
    devices: usize,
    seed: u64,
    policy: PolicyKind,
    overlap: bool,
    workers: usize,
) {
    let rt = Runtime::shared().unwrap();
    let arts = ArtifactSet::load(rt, &root().join(config)).unwrap();
    let dims = ModelDims::from_config_json(&arts.manifest.raw_config).unwrap();
    let params = ParamSet::init(&dims, seed);
    let corpus = MarkovCorpus::new(dims.v, seed ^ 0x5EED);
    let s = corpus.sample(0, dims.t);
    // adjoint_batch: 0 (auto) — with post-ISSUE-5 artifacts this whole
    // sweep runs the *batched* dispatch, which must stay bit-identical
    // across backends exactly like the single-item path did.
    let sched = SchedCfg { policy, overlap, ..Default::default() };

    let mut fleet = Fleet::new(
        TopologyCfg { devices, ..Default::default() },
        dims.k,
    )
    .unwrap();
    let fwd =
        pipeline::forward(&arts, &dims, &params, &mut fleet, &s.tokens, &s.targets).unwrap();
    let timing = overlap.then_some(&fwd.timing);

    let mut run = |exec: &mut dyn Executor| {
        let mut grads = GradSet::zeros(&dims);
        let mut pool = StagePool::new();
        let out = adjoint::backward_pooled(
            &arts, &dims, &params, &mut fleet, &mut grads, &sched, timing, &mut pool, exec,
        )
        .unwrap();
        (grads, out)
    };

    let (g_sim, o_sim) = run(&mut SimExecutor::new());
    let mut threaded = ThreadedExecutor::new(workers);
    let (g_thr, o_thr) = run(&mut threaded);
    let mut process = process_executor(workers);
    let (g_proc, o_proc) = run(&mut process);

    let ctx = format!(
        "{config} Υ={devices} seed={seed} policy={policy} overlap={overlap} workers={workers}"
    );
    assert_grads_bit_identical(&g_sim, &g_thr, &ctx);
    assert_eq!(o_sim.vjp_units, o_thr.vjp_units, "{ctx}: vjp_units");
    assert_eq!(o_sim.calls, o_thr.calls, "{ctx}: calls");
    assert_grads_bit_identical(&g_sim, &g_proc, &format!("{ctx} [process]"));
    assert_eq!(o_sim.vjp_units, o_proc.vjp_units, "{ctx}: process vjp_units");
    assert_eq!(o_sim.calls, o_proc.calls, "{ctx}: process calls");

    // Plan consistency: both measured plans schedule the same item set on
    // the same device partition under the same caps (service times are
    // measured, so spans differ in *when*, never in *what* or *where*).
    let items = plan_chunks(dims.k, dims.t, dims.c).unwrap();
    for (o, which) in [(&o_sim, "sim"), (&o_thr, "threaded"), (&o_proc, "process")] {
        assert_eq!(
            o.plan.schedule.scheduled_items(),
            items.len(),
            "{ctx}: {which} plan dropped items"
        );
        plan_respects_slot_caps(&o.plan, fleet.cfg.mig_slots);
        for d in &o.plan.schedule.devices {
            for span in &d.spans {
                assert_eq!(
                    fleet.device_of_layer(items[span.item].layer),
                    d.device,
                    "{ctx}: {which} plan violated placement"
                );
            }
        }
    }
    for (ds, dt) in o_sim.plan.schedule.devices.iter().zip(&o_thr.plan.schedule.devices) {
        assert_eq!(ds.spans.len(), dt.spans.len(), "{ctx}: per-device span counts");
    }

    // Trace structural equality (PR 9): the modeled spans (analytic plan
    // backbone + offload model) are a pure function of the config, so all
    // three backends must record the identical span multiset. Wall-only
    // spans — worker Gather/Launch, the coordinator Reduce — exist only
    // on live backends and are excluded by the virt_dur filter.
    let modeled = |o: &adjoint::AdjointOutput| {
        let evs: Vec<_> = o.trace.iter().copied().filter(|e| e.virt_dur_ns > 0).collect();
        span_multiset(&evs)
    };
    let reference = modeled(&o_sim);
    assert!(!reference.is_empty(), "{ctx}: sim recorded no modeled spans");
    assert_eq!(reference, modeled(&o_thr), "{ctx}: threaded modeled spans diverged");
    assert_eq!(reference, modeled(&o_proc), "{ctx}: process modeled spans diverged");
}

#[test]
fn executors_bit_identical_across_seeds_policies_overlap() {
    if !have("tiny") {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    for seed in [5u64, 23] {
        for devices in [1usize, 2] {
            for policy in PolicyKind::ALL {
                for overlap in [false, true] {
                    compare_backends("tiny", devices, seed, policy, overlap, 0);
                }
            }
        }
    }
}

#[test]
fn worker_cap_below_fleet_size_still_bit_identical() {
    if !have("tiny") {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    // 2 devices multiplexed onto 1 worker thread: still the same pinned
    // per-lane order, still the same bits.
    compare_backends("tiny", 2, 7, PolicyKind::Lpt, false, 1);
}

// ---------------------------------------------------------------------------
// Batched dispatch (ISSUE 5): bit-identical GradSets across
// {single-item, batched} × {sim, threaded} × batch widths, with the call
// count dropping ~M× — and the pre-batching-artifact fallback staying on
// the single-item path.
// ---------------------------------------------------------------------------

/// One forward, then one backward per (width, executor); returns the
/// GradSet + AdjointOutput of each run, all against identical activations.
fn backward_grid(
    config: &str,
    devices: usize,
    seed: u64,
    widths: &[usize],
) -> Vec<(usize, &'static str, GradSet, adjoint_sharding::adjoint::AdjointOutput)> {
    let rt = Runtime::shared().unwrap();
    let arts = ArtifactSet::load(rt, &root().join(config)).unwrap();
    let dims = ModelDims::from_config_json(&arts.manifest.raw_config).unwrap();
    let params = ParamSet::init(&dims, seed);
    let corpus = MarkovCorpus::new(dims.v, seed ^ 0xBA7C);
    let s = corpus.sample(0, dims.t);
    let mut fleet =
        Fleet::new(TopologyCfg { devices, ..Default::default() }, dims.k).unwrap();
    pipeline::forward(&arts, &dims, &params, &mut fleet, &s.tokens, &s.targets).unwrap();

    let mut out = Vec::new();
    for &width in widths {
        let sched = SchedCfg { adjoint_batch: width, ..Default::default() };
        let mut runs: Vec<(&'static str, Box<dyn Executor>)> = vec![
            ("sim", Box::new(SimExecutor::new())),
            ("threaded", Box::new(ThreadedExecutor::new(0))),
            ("process", Box::new(process_executor(0))),
        ];
        for (label, exec) in runs.iter_mut() {
            let mut grads = GradSet::zeros(&dims);
            let mut pool = StagePool::new();
            let o = adjoint::backward_pooled(
                &arts,
                &dims,
                &params,
                &mut fleet,
                &mut grads,
                &sched,
                None,
                &mut pool,
                exec.as_mut(),
            )
            .unwrap();
            out.push((width, *label, grads, o));
        }
    }
    out
}

#[test]
fn batched_widths_bit_identical_to_single_item() {
    if !have("tiny") {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let rt = Runtime::shared().unwrap();
    let arts = ArtifactSet::load(rt, &root().join("tiny")).unwrap();
    let dims = ModelDims::from_config_json(&arts.manifest.raw_config).unwrap();
    let static_m = adjoint_sharding::exec::batched_entry_width(
        arts.manifest.entry("layer_adjoint_grad_batched").unwrap(),
    )
    .unwrap();
    let chunks = dims.num_chunks();
    assert!(chunks >= 3, "tiny must have ≥ 3 chunks/layer for ragged coverage");
    drop(arts);

    // Widths: 1 = single-item entry; 2 = batched, even groups; 3 =
    // batched with a ragged (zero-padded) tail; 0 = auto (the full
    // static M). Every combination must produce the same bits.
    let grid = backward_grid("tiny", 2, 5, &[1, 2, 3, 0]);
    let (_, _, reference, ref_out) = &grid[0]; // width 1, sim
    assert_eq!(ref_out.calls, (dims.k * chunks) as u64, "single-item call count");

    for (width, label, grads, o) in &grid {
        let eff = adjoint_sharding::exec::resolve_adjoint_batch(*width, Some(static_m));
        let ctx = format!("width={width} (effective {eff}) exec={label}");
        assert_grads_bit_identical(grads, reference, &ctx);
        assert_eq!(o.vjp_units, ref_out.vjp_units, "{ctx}: vjp_units");
        // Calls drop ~M×: one per group, groups = K · ⌈chunks/eff⌉ here
        // (each layer is one contiguous run).
        let expect = (dims.k * ((chunks + eff - 1) / eff)) as u64;
        assert_eq!(o.calls, expect, "{ctx}: dispatch count");
        if eff > 1 {
            assert!(o.calls < ref_out.calls, "{ctx}: batching must cut dispatches");
        }
    }
}

/// Strip one entry from a manifest.json text (json.dump indent=1 format)
/// by brace-depth scanning — builds the pre-batching artifact set the
/// fallback contract is tested against.
fn strip_entry(manifest: &str, entry: &str) -> String {
    let needle = format!("\"{entry}\":");
    let start = manifest.find(&needle).expect("entry present in manifest");
    let bytes = manifest.as_bytes();
    let mut depth = 0usize;
    let mut end = start;
    for (i, &b) in bytes.iter().enumerate().skip(start) {
        match b {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    end = i + 1;
                    break;
                }
            }
            _ => {}
        }
    }
    // Swallow the trailing comma (entry mid-object) or the preceding one
    // (entry last in the object).
    let mut head = manifest[..start].to_string();
    let mut tail = &manifest[end..];
    if let Some(rest) = tail.trim_start().strip_prefix(',') {
        tail = rest;
    } else {
        head.truncate(head.trim_end().trim_end_matches(',').len());
    }
    format!("{head}{tail}")
}

#[test]
fn memcost_transient_forms_match_manifest() {
    if !have("tiny") {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    use adjoint_sharding::memcost;
    let rt = Runtime::shared().unwrap();
    let arts = ArtifactSet::load(rt, &root().join("tiny")).unwrap();
    let dims = ModelDims::from_config_json(&arts.manifest.raw_config).unwrap();

    let single = arts.manifest.entry("layer_adjoint_grad").unwrap();
    assert_eq!(
        memcost::adjoint_single_transient_bytes(&dims),
        (single.input_bytes() + single.output_bytes()) as u64,
        "single-item closed form drifted from the lowered artifact"
    );
    let batched = arts.manifest.entry("layer_adjoint_grad_batched").unwrap();
    let m = adjoint_sharding::exec::batched_entry_width(batched).unwrap() as u64;
    assert_eq!(
        memcost::adjoint_batched_transient_bytes(&dims, m),
        (batched.input_bytes() + batched.output_bytes()) as u64,
        "batched closed form drifted from the lowered artifact"
    );
}

#[test]
fn pre_batching_artifacts_fall_back_to_single_item_path() {
    if !have("tiny") {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    // Build a pre-ISSUE-5 artifact set: same HLO files, manifest without
    // the batched entry.
    let src = root().join("tiny");
    let dir = std::env::temp_dir().join(format!("adjsh_prebatch_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for f in std::fs::read_dir(&src).unwrap() {
        let f = f.unwrap();
        let name = f.file_name();
        if name != "manifest.json" {
            std::fs::copy(f.path(), dir.join(&name)).unwrap();
        }
    }
    let manifest = std::fs::read_to_string(src.join("manifest.json")).unwrap();
    let stripped = strip_entry(&manifest, "layer_adjoint_grad_batched");
    std::fs::write(dir.join("manifest.json"), &stripped).unwrap();

    let rt = Runtime::shared().unwrap();
    let arts = ArtifactSet::load(rt.clone(), &dir).unwrap();
    assert!(
        arts.manifest.entry("layer_adjoint_grad_batched").is_err(),
        "strip failed: batched entry still in manifest"
    );
    let dims = ModelDims::from_config_json(&arts.manifest.raw_config).unwrap();
    let params = ParamSet::init(&dims, 5);
    let corpus = MarkovCorpus::new(dims.v, 5 ^ 0xBA7C);
    let s = corpus.sample(0, dims.t);
    let mut fleet = Fleet::new(TopologyCfg::default(), dims.k).unwrap();
    pipeline::forward(&arts, &dims, &params, &mut fleet, &s.tokens, &s.targets).unwrap();

    // Auto width against the stripped set must take the single-item path
    // (one call per item) and match the full set's gradients bit for bit.
    let mut grads = GradSet::zeros(&dims);
    let mut pool = StagePool::new();
    let o = adjoint::backward_pooled(
        &arts,
        &dims,
        &params,
        &mut fleet,
        &mut grads,
        &SchedCfg::default(),
        None,
        &mut pool,
        &mut SimExecutor::new(),
    )
    .unwrap();
    let items = plan_chunks(dims.k, dims.t, dims.c).unwrap();
    assert_eq!(o.calls, items.len() as u64, "fallback must dispatch per item");
    assert_eq!(o.overlap_s, 0.0, "single-item path has no overlap");

    let batched_grid = backward_grid("tiny", 1, 5, &[0]);
    assert_grads_bit_identical(&grads, &batched_grid[0].2, "pre-batching fallback");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn worker_trainer_steps_match_sim_trainer() {
    if !have("tiny") {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    use adjoint_sharding::config::RunConfig;
    use adjoint_sharding::exec::ExecutorKind;
    use adjoint_sharding::train::Trainer;

    // The trainer builds its ProcessExecutor itself, so point the worker
    // re-exec at the adjsh binary cargo built for this test run.
    std::env::set_var("ADJSH_WORKER_BIN", env!("CARGO_BIN_EXE_adjsh"));

    let mut losses = Vec::new();
    for kind in ExecutorKind::ALL {
        let rt = Runtime::shared().unwrap();
        let mut cfg = RunConfig::load(&root(), "tiny").unwrap();
        cfg.topology.devices = 2.min(cfg.dims.k);
        cfg.exec.kind = kind;
        cfg.log_every = usize::MAX;
        let corpus = Box::new(MarkovCorpus::new(cfg.dims.v, 3));
        let mut tr = Trainer::new(rt, cfg, corpus).unwrap();
        let mut run_losses = Vec::new();
        for _ in 0..3 {
            run_losses.push(tr.step().unwrap().loss);
        }
        losses.push(run_losses);
    }
    // Whole optimization trajectories coincide: identical grads → identical
    // Adam updates → identical next-step losses.
    for (i, kind) in ExecutorKind::ALL.iter().enumerate().skip(1) {
        assert_eq!(losses[0], losses[i], "sim vs {kind} training trajectories diverged");
    }
}

#[test]
fn traced_run_bit_identical_to_untraced() {
    if !have("tiny") {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    use adjoint_sharding::config::RunConfig;
    use adjoint_sharding::exec::ExecutorKind;
    use adjoint_sharding::train::Trainer;

    std::env::set_var("ADJSH_WORKER_BIN", env!("CARGO_BIN_EXE_adjsh"));
    let trace_path =
        std::env::temp_dir().join(format!("adjsh_trace_{}.json", std::process::id()));
    for kind in ExecutorKind::ALL {
        // Recording is always on; `--trace` only gates the file write at
        // the end of the run — so the traced run must land on the exact
        // same parameters (identical grads → identical eval-loss bits).
        let mut evals = Vec::new();
        for traced in [false, true] {
            let rt = Runtime::shared().unwrap();
            let mut cfg = RunConfig::load(&root(), "tiny").unwrap();
            cfg.topology.devices = 2.min(cfg.dims.k);
            cfg.exec.kind = kind;
            cfg.log_every = usize::MAX;
            cfg.obs.trace = traced.then(|| trace_path.clone());
            let corpus = Box::new(MarkovCorpus::new(cfg.dims.v, 11));
            let mut tr = Trainer::new(rt, cfg, corpus).unwrap();
            tr.run(2).unwrap();
            evals.push(tr.eval_loss(1).unwrap());
        }
        assert_eq!(
            evals[0].to_bits(),
            evals[1].to_bits(),
            "{kind}: --trace perturbed the training trajectory"
        );
        let text = std::fs::read_to_string(&trace_path).unwrap();
        let events = adjoint_sharding::obs::parse_chrome_trace(&text).unwrap();
        assert!(!events.is_empty(), "{kind}: traced run wrote an empty trace");
    }
    std::fs::remove_file(&trace_path).ok();
}
