//! Executor equivalence (ISSUE 3): `SimExecutor` and `ThreadedExecutor`
//! must be interchangeable — bit-identical gradients, identical
//! `vjp_units`/`calls`, and a consistent `BackwardPlan` — across seeds,
//! scheduling policies (fifo | lpt | layer-major), `--overlap` on/off,
//! fleet sizes, and worker caps.
//!
//! Host-side tests (dispatch-contract invariants) run everywhere; the
//! PJRT equivalence sweep skips with a message when `make artifacts`
//! hasn't run.

use std::path::{Path, PathBuf};

use adjoint_sharding::adjoint::{self, StagePool};
use adjoint_sharding::config::{ModelDims, SchedCfg, TopologyCfg};
use adjoint_sharding::data::{Corpus, MarkovCorpus};
use adjoint_sharding::exec::{plan_dispatch, Executor, SimExecutor, ThreadedExecutor};
use adjoint_sharding::model::{GradSet, ParamSet};
use adjoint_sharding::pipeline;
use adjoint_sharding::runtime::{ArtifactSet, Runtime};
use adjoint_sharding::schedule::{BackwardPlan, DeviceSchedule, PolicyKind};
use adjoint_sharding::sharding::plan_chunks;
use adjoint_sharding::topology::Fleet;

// ---------------------------------------------------------------------------
// Host-side: dispatch-contract invariants (no artifacts needed).
// ---------------------------------------------------------------------------

/// Max number of spans simultaneously in flight on one device's timeline.
fn max_concurrency(d: &DeviceSchedule) -> usize {
    d.spans
        .iter()
        .map(|s| {
            d.spans
                .iter()
                .filter(|o| o.start_s < s.end_s - 1e-12 && o.end_s > s.start_s + 1e-12)
                .count()
        })
        .max()
        .unwrap_or(0)
}

fn plan_respects_slot_caps(plan: &BackwardPlan, slots: usize) {
    for d in &plan.schedule.devices {
        assert!(
            max_concurrency(d) <= slots,
            "device {} exceeded its {slots} MIG slots",
            d.device
        );
    }
}

#[test]
fn dispatch_contract_invariants_across_seeds_and_policies() {
    for seed in [0u64, 9, 77] {
        for devices in [1usize, 2, 3] {
            for policy in PolicyKind::ALL {
                let dims = ModelDims {
                    name: "exec".into(),
                    v: 16,
                    p: 8,
                    n: 6,
                    k: 3 + (seed as usize % 3),
                    t: 32,
                    w: 8,
                    c: 8,
                    eps: 1e-6,
                };
                if devices > dims.k {
                    continue;
                }
                let topo = TopologyCfg { devices, mig_slots: 2, ..Default::default() };
                let fleet = Fleet::new(topo, dims.k).unwrap();
                let items = plan_chunks(dims.k, dims.t, dims.c).unwrap();
                let sched = SchedCfg { policy, overlap: false };
                let caps: Vec<Option<u64>> = vec![Some(1 << 20); devices];
                let d = plan_dispatch(&dims, &fleet, &items, &sched, 4096, &caps).unwrap();

                // Every item scheduled exactly once, on its owner, queues
                // ascending (the pinned reduction order).
                let mut seen = vec![false; items.len()];
                for (dev, q) in d.queues.iter().enumerate() {
                    assert!(q.windows(2).all(|w| w[0] < w[1]));
                    for &id in q {
                        assert!(!seen[id], "item {id} scheduled twice");
                        seen[id] = true;
                        assert_eq!(fleet.device_of_layer(items[id].layer), dev);
                    }
                }
                assert!(seen.iter().all(|&s| s));
                assert_eq!(d.plan.schedule.scheduled_items(), items.len());
                plan_respects_slot_caps(&d.plan, 2);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT: sim ≡ threaded, bit for bit. Skips without artifacts.
// ---------------------------------------------------------------------------

fn root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have(name: &str) -> bool {
    root().join(name).join("manifest.json").exists()
}

fn assert_grads_bit_identical(a: &GradSet, b: &GradSet, ctx: &str) {
    for (k, (la, lb)) in a.layers.iter().zip(&b.layers).enumerate() {
        for (i, (ta, tb)) in la.0.iter().zip(&lb.0).enumerate() {
            assert_eq!(
                ta.data(),
                tb.data(),
                "{ctx}: layer {k} grad {i} differs between executors"
            );
        }
    }
    assert_eq!(a.omega.data(), b.omega.data(), "{ctx}: dΩ differs");
}

/// One forward, then the same backward phase under both executors against
/// the same activations — the isolation that makes bit-equality a fair
/// (and required) assertion.
fn compare_backends(
    config: &str,
    devices: usize,
    seed: u64,
    policy: PolicyKind,
    overlap: bool,
    workers: usize,
) {
    let rt = Runtime::shared().unwrap();
    let arts = ArtifactSet::load(rt, &root().join(config)).unwrap();
    let dims = ModelDims::from_config_json(&arts.manifest.raw_config).unwrap();
    let params = ParamSet::init(&dims, seed);
    let corpus = MarkovCorpus::new(dims.v, seed ^ 0x5EED);
    let s = corpus.sample(0, dims.t);
    let sched = SchedCfg { policy, overlap };

    let mut fleet = Fleet::new(
        TopologyCfg { devices, ..Default::default() },
        dims.k,
    )
    .unwrap();
    let fwd =
        pipeline::forward(&arts, &dims, &params, &mut fleet, &s.tokens, &s.targets).unwrap();
    let timing = overlap.then_some(&fwd.timing);

    let mut run = |exec: &mut dyn Executor| {
        let mut grads = GradSet::zeros(&dims);
        let mut pool = StagePool::new();
        let out = adjoint::backward_pooled(
            &arts, &dims, &params, &mut fleet, &mut grads, &sched, timing, &mut pool, exec,
        )
        .unwrap();
        (grads, out)
    };

    let (g_sim, o_sim) = run(&mut SimExecutor);
    let mut threaded = ThreadedExecutor::new(workers);
    let (g_thr, o_thr) = run(&mut threaded);

    let ctx = format!(
        "{config} Υ={devices} seed={seed} policy={policy} overlap={overlap} workers={workers}"
    );
    assert_grads_bit_identical(&g_sim, &g_thr, &ctx);
    assert_eq!(o_sim.vjp_units, o_thr.vjp_units, "{ctx}: vjp_units");
    assert_eq!(o_sim.calls, o_thr.calls, "{ctx}: calls");

    // Plan consistency: both measured plans schedule the same item set on
    // the same device partition under the same caps (service times are
    // measured, so spans differ in *when*, never in *what* or *where*).
    let items = plan_chunks(dims.k, dims.t, dims.c).unwrap();
    for (o, which) in [(&o_sim, "sim"), (&o_thr, "threaded")] {
        assert_eq!(
            o.plan.schedule.scheduled_items(),
            items.len(),
            "{ctx}: {which} plan dropped items"
        );
        plan_respects_slot_caps(&o.plan, fleet.cfg.mig_slots);
        for d in &o.plan.schedule.devices {
            for span in &d.spans {
                assert_eq!(
                    fleet.device_of_layer(items[span.item].layer),
                    d.device,
                    "{ctx}: {which} plan violated placement"
                );
            }
        }
    }
    for (ds, dt) in o_sim.plan.schedule.devices.iter().zip(&o_thr.plan.schedule.devices) {
        assert_eq!(ds.spans.len(), dt.spans.len(), "{ctx}: per-device span counts");
    }
}

#[test]
fn executors_bit_identical_across_seeds_policies_overlap() {
    if !have("tiny") {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    for seed in [5u64, 23] {
        for devices in [1usize, 2] {
            for policy in PolicyKind::ALL {
                for overlap in [false, true] {
                    compare_backends("tiny", devices, seed, policy, overlap, 0);
                }
            }
        }
    }
}

#[test]
fn worker_cap_below_fleet_size_still_bit_identical() {
    if !have("tiny") {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    // 2 devices multiplexed onto 1 worker thread: still the same pinned
    // per-lane order, still the same bits.
    compare_backends("tiny", 2, 7, PolicyKind::Lpt, false, 1);
}

#[test]
fn threaded_trainer_steps_match_sim_trainer() {
    if !have("tiny") {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    use adjoint_sharding::config::RunConfig;
    use adjoint_sharding::exec::ExecutorKind;
    use adjoint_sharding::train::Trainer;

    let mut losses = Vec::new();
    for kind in ExecutorKind::ALL {
        let rt = Runtime::shared().unwrap();
        let mut cfg = RunConfig::load(&root(), "tiny").unwrap();
        cfg.topology.devices = 2.min(cfg.dims.k);
        cfg.exec.kind = kind;
        cfg.log_every = usize::MAX;
        let corpus = Box::new(MarkovCorpus::new(cfg.dims.v, 3));
        let mut tr = Trainer::new(rt, cfg, corpus).unwrap();
        let mut run_losses = Vec::new();
        for _ in 0..3 {
            run_losses.push(tr.step().unwrap().loss);
        }
        losses.push(run_losses);
    }
    // Whole optimization trajectories coincide: identical grads → identical
    // Adam updates → identical next-step losses.
    assert_eq!(losses[0], losses[1], "sim vs threaded training trajectories diverged");
}
