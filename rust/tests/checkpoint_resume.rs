//! Crash-safe checkpoint/resume (ISSUE 7): a run killed at step k and
//! resumed from its newest verified checkpoint must continue
//! *bit-identically* — same losses, same parameter bits — as the run
//! that was never interrupted. Torn or bit-flipped checkpoint files must
//! be detected by the CRC trailer and skipped in favor of the newest
//! file that verifies. Host-only tests exercise the format; the
//! trainer-level tests skip without `make artifacts`.

use std::path::{Path, PathBuf};

use adjoint_sharding::config::{ModelDims, RunConfig};
use adjoint_sharding::data::MarkovCorpus;
use adjoint_sharding::model::ParamSet;
use adjoint_sharding::runtime::Runtime;
use adjoint_sharding::tensor::Tensor;
use adjoint_sharding::train::checkpoint::{
    latest_good, load_train_checkpoint, save_train_checkpoint, AdamState, TrainCheckpoint,
};
use adjoint_sharding::train::Trainer;

fn root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have(name: &str) -> bool {
    root().join(name).join("manifest.json").exists()
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("adjsh_ckres_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn assert_params_identical(a: &ParamSet, b: &ParamSet, ctx: &str) {
    assert_eq!(a.layers.len(), b.layers.len(), "{ctx}: layer count");
    for (k, (la, lb)) in a.layers.iter().zip(&b.layers).enumerate() {
        for (i, (ta, tb)) in la.0.iter().zip(&lb.0).enumerate() {
            assert_eq!(ta.data(), tb.data(), "{ctx}: layer {k} tensor {i} differs");
        }
    }
    assert_eq!(a.omega.data(), b.omega.data(), "{ctx}: Ω differs");
    assert_eq!(a.embed.data(), b.embed.data(), "{ctx}: embedding differs");
}

// ---------------------------------------------------------------------------
// Format-level tests (host-only, no artifacts needed).
// ---------------------------------------------------------------------------

fn dims() -> ModelDims {
    ModelDims { name: "t".into(), v: 8, p: 4, n: 4, k: 2, t: 8, w: 8, c: 4, eps: 1e-6 }
}

/// A checkpoint with distinguishable content per step, shaped like a real
/// trainer snapshot (one moment bank entry per param tensor).
fn sample_ckpt(step: u64) -> TrainCheckpoint {
    let d = dims();
    let params = ParamSet::init(&d, 7 + step);
    let adam = |ts: &[Tensor]| AdamState {
        step,
        m: ts.to_vec(),
        v: ts.iter().map(|t| Tensor::zeros(t.shape())).collect(),
    };
    TrainCheckpoint {
        step,
        seed: 7,
        opt_layers: params.layers.iter().map(|l| adam(&l.0)).collect(),
        opt_head: adam(std::slice::from_ref(&params.omega)),
        rng_state: 0x9e3779b97f4a7c15 ^ step,
        rng_spare: (step % 2 == 0).then_some(0.5),
        params,
    }
}

#[test]
fn torn_newest_checkpoint_falls_back_to_previous() {
    let dir = tmpdir("torn");
    let p1 = save_train_checkpoint(&sample_ckpt(1), &dir).unwrap();
    let p2 = save_train_checkpoint(&sample_ckpt(2), &dir).unwrap();

    // Tear the newest file as a crash mid-write would (the atomic
    // tmp+rename protocol prevents this for our own writes; the loader
    // must still survive a file torn by other means).
    let bytes = std::fs::read(&p2).unwrap();
    std::fs::write(&p2, &bytes[..bytes.len() / 2]).unwrap();
    assert!(load_train_checkpoint(&p2).is_err(), "torn file must not load");

    let (path, ck) = latest_good(&dir).unwrap().expect("step 1 must still verify");
    assert_eq!(path, p1);
    assert_eq!(ck.step, 1);
    assert_params_identical(&ck.params, &sample_ckpt(1).params, "fallback checkpoint");
}

#[test]
fn flipped_bits_never_load() {
    let dir = tmpdir("flip");
    let p = save_train_checkpoint(&sample_ckpt(3), &dir).unwrap();
    let clean = std::fs::read(&p).unwrap();
    // Flip one bit at a sweep of offsets across the file — header, body,
    // and trailer alike — and require a clean load error every time.
    let stride = (clean.len() / 41).max(1);
    for off in (0..clean.len()).step_by(stride) {
        let mut bad = clean.clone();
        bad[off] ^= 0x20;
        std::fs::write(&p, &bad).unwrap();
        assert!(load_train_checkpoint(&p).is_err(), "bit flip at {off} loaded");
    }
    std::fs::write(&p, &clean).unwrap();
    assert_eq!(load_train_checkpoint(&p).unwrap().step, 3, "pristine file must load");
}

// ---------------------------------------------------------------------------
// Trainer-level kill/resume equivalence. Skips without artifacts.
// ---------------------------------------------------------------------------

fn trainer(ckdir: Option<&Path>) -> Trainer {
    let rt = Runtime::shared().unwrap();
    let mut cfg = RunConfig::load(&root(), "tiny").unwrap();
    cfg.checkpoint_dir = ckdir.map(Path::to_path_buf);
    let corpus = Box::new(MarkovCorpus::new(cfg.dims.v, 0));
    Trainer::new(rt, cfg, corpus).unwrap()
}

#[test]
fn kill_and_resume_is_bit_identical() {
    if !have("tiny") {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    // Reference: 4 uninterrupted steps.
    let mut unbroken = trainer(None);
    let mut ref_losses = Vec::new();
    for _ in 0..4 {
        ref_losses.push(unbroken.step().unwrap().loss);
    }

    // "Crashed" run: 2 steps, checkpoint, drop the trainer (the crash).
    let dir = tmpdir("resume");
    let mut dying = trainer(Some(&dir));
    for i in 0..2 {
        assert_eq!(dying.step().unwrap().loss.to_bits(), ref_losses[i].to_bits());
    }
    dying.save_train_checkpoint(&dir).unwrap();
    drop(dying);

    // Resume in a fresh trainer and run the remaining 2 steps: the loss
    // trajectory and the final parameter bits must match the run that
    // never died — optimizer moments, RNG, and data stream included.
    let mut resumed = trainer(Some(&dir));
    assert_eq!(resumed.resume_latest(&dir).unwrap(), Some(2), "must resume at step 2");
    for want in &ref_losses[2..] {
        let got = resumed.step().unwrap().loss;
        assert_eq!(got.to_bits(), want.to_bits(), "post-resume loss diverged");
    }
    assert_params_identical(&resumed.params, &unbroken.params, "post-resume params");
}

#[test]
fn resume_refuses_foreign_checkpoints() {
    if !have("tiny") {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let mut t = trainer(None);
    t.step().unwrap();

    // Wrong seed: a checkpoint from a different run must be refused.
    let mut ck = t.train_checkpoint();
    ck.seed ^= 1;
    let err = t.resume_train_checkpoint(ck).unwrap_err();
    assert!(format!("{err:#}").contains("seed"), "seed mismatch must be named");

    // Wrong shapes: a checkpoint from different dims must be refused
    // outright, never partially adopted.
    let mut ck = t.train_checkpoint();
    ck.params.omega = Tensor::zeros(&[1, 1]);
    assert!(t.resume_train_checkpoint(ck).is_err(), "Ω shape mismatch accepted");
    let before = t.params.clone();
    let mut ck = t.train_checkpoint();
    ck.params.layers[0].0.push(Tensor::zeros(&[1]));
    assert!(t.resume_train_checkpoint(ck).is_err(), "extra layer tensor accepted");
    assert_params_identical(&t.params, &before, "rejected resume must not touch params");
}

#[test]
fn periodic_checkpoints_are_written_and_resumable() {
    if !have("tiny") {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let dir = tmpdir("periodic");
    let mut t = trainer(Some(&dir));
    t.cfg.checkpoint_every = 1;
    t.run(2).unwrap();
    let (path, ck) = latest_good(&dir).unwrap().expect("run(2) must have checkpointed");
    assert_eq!(ck.step, 2, "newest checkpoint is the step-2 snapshot ({})", path.display());
    assert_params_identical(&ck.params, &t.params, "checkpointed params");
}
