//! Randomized property tests for the event-driven backward scheduler
//! (proptest is unavailable offline; cases come from the crate's seeded
//! RNG — every failure reports its case index and inputs for replay).
//!
//! The ISSUE-level invariants:
//!   (i)  the overlapped (paralleled) plan's step end never exceeds the
//!        sequential baseline's;
//!   (ii) schedules never exceed per-device memory-admission caps
//!        (time-resolved, recomputed independently from the spans);
//!   (iii) every work item is scheduled exactly once, on its own device,
//!        with non-overlapping spans per slot — under every policy.

use adjoint_sharding::rng::Rng;
use adjoint_sharding::schedule::{
    makespan_fifo, overlap_ready_times, plan_backward, schedule_items, PolicyKind, SchedItem,
    Schedule,
};
use adjoint_sharding::sharding::{assign_layers, plan_batches, plan_chunks};

const CASES: usize = 150;

/// Random fleet-shaped item set: K layers on Υ devices, random costs,
/// uniform transient bytes.
fn random_items(rng: &mut Rng) -> (Vec<SchedItem>, usize, usize, u64) {
    let k = 1 + rng.below(12) as usize;
    let devices = 1 + rng.below(k as u64) as usize;
    let per_layer = 1 + rng.below(8) as usize;
    let mem = 1 + rng.below(1000);
    let assignment = assign_layers(k, devices).unwrap();
    let mut items = Vec::new();
    for layer in 0..k {
        for _ in 0..per_layer {
            items.push(SchedItem {
                id: items.len(),
                device: assignment.device_of_layer[layer],
                layer,
                cost_s: 1e-4 + rng.uniform() * 1e-2,
                ready_at: rng.uniform() * 1e-2,
                mem_bytes: mem,
            });
        }
    }
    let slots = 1 + rng.below(7) as usize;
    (items, devices, slots, mem)
}

/// Time-resolved in-flight bytes, recomputed from the spans alone.
fn max_concurrent_bytes(s: &Schedule, mem: u64) -> u64 {
    let mut worst = 0u64;
    for d in &s.devices {
        for a in &d.spans {
            // In-flight set at a's start: every span covering that instant.
            let live = d
                .spans
                .iter()
                .filter(|b| b.start_s <= a.start_s + 1e-12 && b.end_s > a.start_s + 1e-12)
                .count() as u64
                * mem;
            worst = worst.max(live);
        }
    }
    worst
}

#[test]
fn prop_every_item_scheduled_exactly_once_across_policies() {
    let mut rng = Rng::new(0x5C4ED);
    for case in 0..CASES {
        let (items, devices, slots, _) = random_items(&mut rng);
        for kind in PolicyKind::ALL {
            let s = schedule_items(&items, devices, slots, &[], kind.policy().as_ref(), false)
                .unwrap_or_else(|e| panic!("case {case} [{kind}]: {e}"));
            // Exactly once, each on its owning device.
            let mut seen: Vec<usize> = Vec::new();
            for d in &s.devices {
                for span in &d.spans {
                    seen.push(span.item);
                    assert_eq!(
                        items[span.item].device, d.device,
                        "case {case} [{kind}]: item {} on wrong device",
                        span.item
                    );
                    assert!(
                        span.start_s >= items[span.item].ready_at - 1e-12,
                        "case {case} [{kind}]: item {} started before release",
                        span.item
                    );
                }
                // Spans on one slot never overlap.
                for slot in 0..d.slots {
                    let mut spans: Vec<_> =
                        d.spans.iter().filter(|s| s.slot == slot).collect();
                    spans.sort_by(|a, b| a.start_s.partial_cmp(&b.start_s).unwrap());
                    for w in spans.windows(2) {
                        assert!(
                            w[0].end_s <= w[1].start_s + 1e-9,
                            "case {case} [{kind}]: slot {slot} overlap"
                        );
                    }
                }
            }
            seen.sort_unstable();
            let want: Vec<usize> = (0..items.len()).collect();
            assert_eq!(seen, want, "case {case} [{kind}]: not a permutation");
        }
    }
}

#[test]
fn prop_memory_caps_never_exceeded() {
    let mut rng = Rng::new(0xCA9);
    for case in 0..CASES {
        let (items, devices, slots, mem) = random_items(&mut rng);
        // Cap between 1 and slots working sets (≥ one item, so the
        // oversized-item escape hatch never engages).
        let width = 1 + rng.below(slots as u64);
        let cap = mem * width;
        let caps: Vec<Option<u64>> = vec![Some(cap); devices];
        for kind in PolicyKind::ALL {
            let s = schedule_items(&items, devices, slots, &caps, kind.policy().as_ref(), false)
                .unwrap_or_else(|e| panic!("case {case} [{kind}]: {e}"));
            for d in &s.devices {
                assert!(
                    d.peak_transient_bytes <= cap,
                    "case {case} [{kind}]: device {} reported peak {} > cap {cap}",
                    d.device,
                    d.peak_transient_bytes
                );
            }
            let observed = max_concurrent_bytes(&s, mem);
            assert!(
                observed <= cap,
                "case {case} [{kind}]: time-resolved concurrency {observed} > cap {cap}"
            );
            assert_eq!(s.scheduled_items(), items.len(), "case {case} [{kind}]: items lost");
        }
    }
}

#[test]
fn prop_overlapped_never_loses_to_sequential() {
    let mut rng = Rng::new(0x0B5);
    // Teeth: plan_backward's fallback makes "≤" hold by construction, so
    // also require that the overlap path genuinely engages — kept plans
    // and strict wins must both show up across the suite.
    let mut kept = 0usize;
    let mut strict_wins = 0usize;
    for case in 0..CASES {
        let k = 1 + rng.below(8) as usize;
        let chunks = 1 + rng.below(8) as usize;
        let c = 8usize;
        let t = c * chunks;
        let w = 1 + rng.below(t as u64) as usize;
        let devices = 1 + rng.below(k as u64) as usize;
        let slots = 1 + rng.below(4) as usize;
        let assignment = assign_layers(k, devices).unwrap();
        let items = plan_chunks(k, t, c).unwrap();
        let sched_items: Vec<SchedItem> = items
            .iter()
            .enumerate()
            .map(|(id, it)| SchedItem {
                id,
                device: assignment.device_of_layer[it.layer],
                layer: it.layer,
                cost_s: 1e-4 + rng.uniform() * 1e-2,
                ready_at: 0.0,
                mem_bytes: 0,
            })
            .collect();
        let layer_secs: Vec<f64> = (0..k).map(|_| 1e-4 + rng.uniform() * 1e-2).collect();
        let head_secs = 1e-4 + rng.uniform() * 1e-2;
        let bcast = rng.uniform() * 1e-3;
        let seq_start: f64 = layer_secs.iter().sum::<f64>() + head_secs + bcast;
        let ready = overlap_ready_times(&items, &layer_secs, head_secs, bcast, c, w);
        assert!(
            ready.iter().all(|&r| r <= seq_start + 1e-9),
            "case {case}: a release past the serial forward"
        );
        for kind in PolicyKind::ALL {
            let pol = kind.policy();
            let seq = plan_backward(
                &sched_items, None, seq_start, devices, slots, &[], pol.as_ref(),
            )
            .unwrap();
            let ov = plan_backward(
                &sched_items,
                Some(&ready),
                seq_start,
                devices,
                slots,
                &[],
                pol.as_ref(),
            )
            .unwrap();
            assert!(
                ov.phase_end_s <= seq.phase_end_s + 1e-9,
                "case {case} [{kind}]: overlapped {} > sequential {}",
                ov.phase_end_s,
                seq.phase_end_s
            );
            assert!(
                ov.backward_s <= ov.sequential_makespan_s + 1e-9,
                "case {case} [{kind}]: backward tail exceeds sequential makespan"
            );
            assert!(
                ov.backward_s >= -1e-12 && ov.phase_end_s >= seq_start - 1e-9,
                "case {case} [{kind}]: phase ended before the forward"
            );
            if ov.schedule.overlapped {
                kept += 1;
                if ov.phase_end_s < seq.phase_end_s - 1e-9 {
                    strict_wins += 1;
                }
            }
        }
    }
    assert!(kept > 0, "overlapped plan was never kept — overlap path never exercised");
    assert!(
        strict_wins > 0,
        "overlap never beat sequential strictly across {CASES} cases — release model inert"
    );
}

#[test]
fn prop_plan_batches_partitions_queues() {
    // ISSUE-5 invariants: every queued item in exactly one group; groups
    // same-layer; group order (and ids within groups) preserve the
    // queue's ascending order; within a layer's contiguous run only the
    // final group is ragged (< m), and no group exceeds m.
    let mut rng = Rng::new(0xBA7C);
    for case in 0..CASES {
        let k = 1 + rng.below(8) as usize;
        let chunks = 1 + rng.below(12) as usize;
        let c = 4usize;
        let t = c * chunks;
        let devices = 1 + rng.below(k as u64) as usize;
        let m = 1 + rng.below(9) as usize;
        let items = plan_chunks(k, t, c).unwrap();
        let assignment = assign_layers(k, devices).unwrap();

        for dev in 0..devices {
            // The executors' queue shape: this device's items, ascending.
            let queue: Vec<usize> = (0..items.len())
                .filter(|&id| assignment.device_of_layer[items[id].layer] == dev)
                .collect();
            let groups = plan_batches(&items, &queue, m)
                .unwrap_or_else(|e| panic!("case {case} dev {dev}: {e}"));

            // Exactly-once coverage in queue order.
            let flat: Vec<usize> = groups.iter().flat_map(|g| g.ids.clone()).collect();
            assert_eq!(flat, queue, "case {case} dev {dev}: groups must tile the queue");

            for (gi, g) in groups.iter().enumerate() {
                assert!(
                    !g.ids.is_empty() && g.ids.len() <= m,
                    "case {case} dev {dev}: group {gi} size {}",
                    g.ids.len()
                );
                assert!(
                    g.ids.iter().all(|&id| items[id].layer == g.layer),
                    "case {case} dev {dev}: group {gi} mixes layers"
                );
                assert!(
                    g.ids.windows(2).all(|w| w[0] < w[1]),
                    "case {case} dev {dev}: group {gi} not ascending"
                );
                // Ragged tail only at the end of a layer's run: a short
                // group must be followed by a different layer (or nothing).
                if g.ids.len() < m {
                    if let Some(next) = groups.get(gi + 1) {
                        assert_ne!(
                            next.layer, g.layer,
                            "case {case} dev {dev}: ragged group {gi} mid-run"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn prop_recovery_replan_covers_orphans_exactly_once() {
    // ISSUE-6 invariants: for random fleets, fault points, and lane
    // counts, the recovery re-plan (a) covers exactly the dead lanes'
    // orphaned items, each exactly once; (b) keeps every recovery queue
    // ascending in global id (the pinned reduction order) with groups
    // tiling it; (c) sends work only to survivors — or back to the dead
    // lane itself on rejoin; (d) never exceeds MIG slot caps.
    use adjoint_sharding::config::{ModelDims, SchedCfg, TopologyCfg};
    use adjoint_sharding::exec::fault::{plan_recovery, split_faults};
    use adjoint_sharding::exec::{plan_dispatch, Fault, FaultPlan};
    use adjoint_sharding::schedule::BackwardPlan;
    use adjoint_sharding::topology::Fleet;
    use std::collections::BTreeSet;

    fn plan_respects_slots(plan: &BackwardPlan, slots: usize, ctx: &str) {
        for d in &plan.schedule.devices {
            for s in &d.spans {
                let live = d
                    .spans
                    .iter()
                    .filter(|o| o.start_s < s.end_s - 1e-12 && o.end_s > s.start_s + 1e-12)
                    .count();
                assert!(live <= slots, "{ctx}: {live} concurrent spans > {slots} MIG slots");
            }
        }
    }

    let mut rng = Rng::new(0xFA17);
    let mut effective = 0usize;
    let mut rejoins = 0usize;
    let mut multi = 0usize;
    for case in 0..CASES {
        // Every 5th case forces the multi-death + rejoin shape (≥ 3
        // lanes, two effective kills, one rejoining) so the teeth below
        // hold by construction; the rest roam freely, including
        // ineffective fault points.
        let force_multi = case % 5 == 0;
        let k = if force_multi { 4 + rng.below(5) as usize } else { 1 + rng.below(8) as usize };
        let chunks = 1 + rng.below(6) as usize;
        let c = 4usize;
        let t = c * chunks;
        let devices = if force_multi {
            3 + rng.below((k - 2) as u64) as usize
        } else {
            1 + rng.below(k as u64) as usize
        };
        let slots = 1 + rng.below(4) as usize;
        let batch = 1 + rng.below(4) as usize;
        let dims =
            ModelDims { name: "p".into(), v: 8, p: 4, n: 4, k, t, w: 4, c, eps: 1e-6 };
        let topo = TopologyCfg { devices, mig_slots: slots, ..Default::default() };
        let fleet = Fleet::new(topo.clone(), k).unwrap();
        let items = plan_chunks(k, t, c).unwrap();
        let dispatch =
            plan_dispatch(&dims, &fleet, &items, &SchedCfg::default(), 4096, &[], batch)
                .unwrap_or_else(|e| panic!("case {case}: dispatch {e}"));

        // Sim lane model: one lane per device. Kill 1 lane (2 when the
        // fleet is big enough), at a random fault point that may land
        // past the queue (ineffective); the only lane must rejoin.
        let n_lanes = devices;
        let lane_items: Vec<usize> = dispatch.queues.iter().map(|q| q.len()).collect();
        let n_dead = if force_multi { 2 } else { 1 };
        let mut kills = Vec::new();
        let mut lanes_hit = BTreeSet::new();
        while kills.len() < n_dead {
            let lane = rng.below(n_lanes as u64) as usize;
            if !lanes_hit.insert(lane) {
                continue;
            }
            // Forced cases pin the fault point inside the queue (always
            // effective) and make exactly the first kill rejoin.
            let after_items = if force_multi {
                rng.below(lane_items[lane].max(1) as u64) as usize
            } else {
                rng.below((lane_items[lane].max(1) * 2) as u64) as usize
            };
            let rejoin =
                if force_multi { kills.is_empty() } else { devices == 1 || rng.chance(0.5) };
            kills.push(Fault::kill(lane, after_items, rejoin));
        }
        let plan = FaultPlan { kills };
        let split = split_faults(&plan, n_lanes, &lane_items)
            .unwrap_or_else(|e| panic!("case {case}: split {e}"));
        // The split keeps exactly the kills whose fault point lands
        // inside the lane's queue.
        for f in &plan.kills {
            assert_eq!(
                split.kill_after(f.lane).is_some(),
                f.after_items < lane_items[f.lane],
                "case {case}: effectiveness filter wrong for lane {}",
                f.lane
            );
        }
        let dead: Vec<(usize, bool)> =
            split.kills.iter().map(|f| (f.lane, f.rejoin)).collect();
        if dead.is_empty() {
            continue; // every kill ineffective — nothing to recover
        }
        effective += 1;
        if dead.len() > 1 {
            multi += 1;
        }

        let rec = plan_recovery(&dims, &topo, &dispatch, n_lanes, &dead)
            .unwrap_or_else(|e| panic!("case {case}: recovery {e}"));

        // (a) orphans = exactly the dead lanes' queues, each item once.
        let mut want_orphans: Vec<usize> =
            dead.iter().flat_map(|&(l, _)| dispatch.queues[l].iter().copied()).collect();
        want_orphans.sort_unstable();
        assert_eq!(rec.orphans, want_orphans, "case {case}: orphan item set");
        let want_layers: BTreeSet<usize> =
            want_orphans.iter().map(|&id| items[id].layer).collect();
        assert_eq!(
            rec.orphan_layers,
            want_layers.iter().copied().collect::<Vec<_>>(),
            "case {case}: orphan layer range"
        );

        let mut covered = Vec::new();
        let dead_set: BTreeSet<usize> = dead.iter().map(|&(l, _)| l).collect();
        for (wi, wave) in rec.waves.iter().enumerate() {
            // (d) each wave's sub-plan respects the MIG slot caps.
            plan_respects_slots(&wave.plan, slots, &format!("case {case} wave {wi}"));
            for rl in &wave.lanes {
                // (c) recovery lands on a survivor, or on the dead lane
                // itself iff it rejoins.
                if dead_set.contains(&rl.lane) {
                    assert!(
                        dead.iter().any(|&(l, r)| l == rl.lane && r),
                        "case {case}: wave {wi} routed work to dead lane {}",
                        rl.lane
                    );
                    rejoins += 1;
                }
                // (b) ascending queue, groups tiling it, same-layer, ≤ batch.
                assert!(
                    rl.queue.windows(2).all(|w| w[0] < w[1]),
                    "case {case}: recovery queue not ascending"
                );
                let flat: Vec<usize> =
                    rl.groups.iter().flat_map(|g| g.ids.clone()).collect();
                assert_eq!(flat, rl.queue, "case {case}: groups must tile the queue");
                for g in &rl.groups {
                    assert!(!g.ids.is_empty() && g.ids.len() <= batch, "case {case}: group size");
                    assert!(
                        g.ids.iter().all(|&id| items[id].layer == g.layer),
                        "case {case}: group mixes layers"
                    );
                }
                covered.extend(rl.queue.iter().copied());
            }
        }
        covered.sort_unstable();
        assert_eq!(covered, want_orphans, "case {case}: waves must cover orphans exactly once");
    }
    // Teeth: the sweep must actually exercise the paths it claims to —
    // guaranteed by the forced every-5th-case shape above.
    assert!(effective >= CASES / 5, "too few effective kills ({effective})");
    assert!(rejoins > 0, "rejoin path never exercised");
    assert!(multi > 0, "multi-death path never exercised");
}

#[test]
fn prop_makespan_fifo_matches_greedy_list_scheduling() {
    // Independent reimplementation of the seed's greedy list makespan.
    fn greedy(times: &[f64], slots: usize) -> f64 {
        let mut load = vec![0.0f64; slots];
        for &t in times {
            let (i, _) = load
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            load[i] += t;
        }
        load.iter().cloned().fold(0.0, f64::max)
    }
    let mut rng = Rng::new(0xF1F0);
    for case in 0..CASES {
        let n = rng.below(40) as usize;
        let slots = 1 + rng.below(12) as usize;
        let times: Vec<f64> = (0..n).map(|_| 1e-3 + rng.uniform()).collect();
        let ours = makespan_fifo(&times, slots);
        let reference = greedy(&times, slots);
        assert!(
            (ours - reference).abs() <= 1e-9 * (1.0 + reference),
            "case {case}: event-driven fifo {ours} != greedy {reference} (n={n}, slots={slots})"
        );
    }
}
