//! Observability integration (PR 9), host-only — no PJRT artifacts
//! needed: sim traces are a pure function of the config (byte-identical
//! Chrome JSON across runs), spill-span bytes are conserved against the
//! topology accountant, and the emitted JSON parses back losslessly
//! through `util::json` — sentinel lanes/keys included. The
//! trainer-level traced-vs-untraced bit-identity and the cross-backend
//! span-multiset equality live in `exec_equivalence.rs` (they need
//! artifacts).

use adjoint_sharding::adjoint;
use adjoint_sharding::config::{ModelDims, SchedCfg, TopologyCfg};
use adjoint_sharding::exec::plan_dispatch;
use adjoint_sharding::obs::{
    chrome_trace_json, parse_chrome_trace, plan_spans, spill_span_bytes, summarize,
    write_chrome_trace, TraceEvent, TraceKind, TraceRecorder, COORD_LANE, NO_KEY,
};
use adjoint_sharding::sharding::plan_chunks;
use adjoint_sharding::topology::Fleet;

fn dims() -> ModelDims {
    ModelDims { name: "obs".into(), v: 16, p: 8, n: 6, k: 3, t: 32, w: 8, c: 8, eps: 1e-6 }
}

/// The deterministic backbone a sim run records: Launch spans synthesized
/// from the analytic `BackwardPlan` — exactly what `backward_pooled`
/// does, minus the execution.
fn synthesize_trace(devices: usize) -> Vec<TraceEvent> {
    let dims = dims();
    let fleet =
        Fleet::new(TopologyCfg { devices, ..Default::default() }, dims.k).unwrap();
    let items = plan_chunks(dims.k, dims.t, dims.c).unwrap();
    let caps: Vec<Option<u64>> = vec![Some(1 << 20); devices];
    let d = plan_dispatch(&dims, &fleet, &items, &SchedCfg::default(), 4096, &caps, 1).unwrap();
    plan_spans(&d.plan.schedule)
}

#[test]
fn sim_trace_is_byte_identical_across_runs() {
    // Two independent plan → spans → JSON pipelines, zero shared state:
    // the emitted document must agree byte for byte.
    let a = chrome_trace_json(&synthesize_trace(2));
    let b = chrome_trace_json(&synthesize_trace(2));
    assert_eq!(a, b, "sim trace is not a pure function of the config");
    assert!(!a.is_empty());

    // And the backbone covers the whole schedule: one Launch per item.
    let spans = synthesize_trace(2);
    let items = plan_chunks(dims().k, dims().t, dims().c).unwrap();
    assert_eq!(spans.len(), items.len(), "plan backbone dropped items");
    assert!(spans.iter().all(|e| e.kind == TraceKind::Launch && e.virt_dur_ns > 0));
}

#[test]
fn deterministic_recorder_zeroes_wall_stamps() {
    let mut rec = TraceRecorder::new(true);
    assert!(rec.deterministic());
    assert_eq!(rec.wall_now_ns(), 0, "deterministic recorder must not read the clock");
    rec.push(TraceEvent::span_wall(0, TraceKind::Gather, 123, 456, NO_KEY, 0));
    rec.extend(vec![TraceEvent::instant(COORD_LANE, TraceKind::Kill, NO_KEY, 0)]);
    let evs = rec.events();
    assert_eq!(evs.len(), 2);
    assert_eq!((evs[0].wall_ns, evs[0].wall_dur_ns), (0, 0), "wall stamps must be zeroed");
}

#[test]
fn spill_span_bytes_match_topology_accounting() {
    // Spill every stored layer off every device, building one Spill span
    // per layer from the bytes `spill_layer` actually moved — the same
    // mechanic `backward_pooled` uses. The span total and the topology
    // accountant must agree exactly (counters conservation).
    let dims = dims();
    let topo = TopologyCfg { devices: 2, offload: true, ..Default::default() };
    let mut fleet = Fleet::new(topo, dims.k).unwrap();
    adjoint::put_synthetic_activations(&dims, &mut fleet, 7);
    let mut events = Vec::new();
    for dev in 0..fleet.devices.len() {
        for layer in 0..dims.k {
            if fleet.device_of_layer(layer) != dev {
                continue;
            }
            let moved = fleet.devices[dev].spill_layer(layer);
            events.push(TraceEvent::span_virt(dev, TraceKind::Spill, 0.0, 1e-6, layer, moved));
        }
    }
    let accounted: u64 = fleet.devices.iter().map(|d| d.spilled_bytes).sum();
    assert!(accounted > 0, "synthetic activations produced nothing to spill");
    assert_eq!(spill_span_bytes(&events), accounted, "spill spans drifted from the accountant");
    assert_eq!(summarize(&events).spilled_bytes, accounted);
}

#[test]
fn trace_json_roundtrips_with_sentinels() {
    let mut events = synthesize_trace(2);
    // Sentinel lane/key cross JSON as -1 and must reconstruct exactly.
    events.push(TraceEvent::span_wall(COORD_LANE, TraceKind::Reduce, 10, 2_500, NO_KEY, 0));
    events.push(TraceEvent::instant(1, TraceKind::Respawn, 2, 0));
    let back = parse_chrome_trace(&chrome_trace_json(&events)).unwrap();
    assert_eq!(back, events, "Chrome JSON parse-back is not lossless");
}

#[test]
fn emit_smoke_trace_when_requested() {
    // CI hook: `ADJSH_TRACE_SMOKE_OUT=/path cargo test --test obs_trace`
    // leaves a freshly emitted trace on disk for the `adjsh trace
    // summary` smoke step in ci.yml; a no-op everywhere else.
    let Ok(path) = std::env::var("ADJSH_TRACE_SMOKE_OUT") else { return };
    write_chrome_trace(std::path::Path::new(&path), &synthesize_trace(2)).unwrap();
}

#[test]
fn written_trace_summarizes_from_disk() {
    // The `adjsh trace summary` path: write → read → parse → summarize.
    let events = synthesize_trace(2);
    let path =
        std::env::temp_dir().join(format!("adjsh_obs_trace_{}.json", std::process::id()));
    write_chrome_trace(&path, &events).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let back = parse_chrome_trace(&text).unwrap();
    assert_eq!(back, events);
    let s = summarize(&back);
    assert_eq!(s.events, events.len());
    assert_eq!(s.lanes.len(), 2, "one summary row per device lane");
    assert!(s.lanes.iter().all(|l| l.utilization() > 0.0));
    let rendered = s.render();
    assert!(rendered.contains("overlap="));
    assert!(rendered.contains("lane 0:"));
}
