//! Serving correctness: the continuous-batching loop must be a pure
//! throughput transformation — every session's token stream is
//! bit-identical to running it alone through `generate::generate`,
//! regardless of batching, arrival interleaving, executor backend, or a
//! snapshot/restore cycle in the middle; and admission never exceeds the
//! memcost-modeled HBM cap.
//!
//! Artifact-gated (run `make artifacts` first); the batched-ABI test
//! additionally requires an artifact set that includes
//! `layer_step_batched` (regenerated sets do; pre-serving sets fall back
//! to the per-session path, which these stream tests still cover).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use adjoint_sharding::config::{ModelDims, ServeCfg};
use adjoint_sharding::exec::{ExecCfg, ExecutorKind};
use adjoint_sharding::generate::{self, DecodeState};
use adjoint_sharding::memcost::ServeAdmission;
use adjoint_sharding::model::ParamSet;
use adjoint_sharding::obs::trace::TraceKind;
use adjoint_sharding::rng::Rng;
use adjoint_sharding::runtime::{ArtifactSet, Manifest, Runtime};
use adjoint_sharding::serve::{
    build_backend, MockBackend, Request, ServeLoop, SimBackend, StepBackend,
};
use adjoint_sharding::tensor::Tensor;

fn root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Artifact dir + dims, without opening a PJRT client (each backend
/// opens its own).
fn tiny() -> Option<(PathBuf, ModelDims)> {
    let dir = root().join("tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts`");
        return None;
    }
    let m = Manifest::load(&dir).unwrap();
    let dims = ModelDims::from_config_json(&m.raw_config).unwrap();
    Some((dir, dims))
}

fn zeros_h(dims: &ModelDims) -> Vec<Tensor> {
    (0..dims.k).map(|_| Tensor::zeros(&[dims.n])).collect()
}

fn mk_loop(
    dir: &Path,
    dims: &ModelDims,
    params: &Arc<ParamSet>,
    exec: ExecCfg,
    max_batch: usize,
    admission: ServeAdmission,
) -> ServeLoop {
    let cfg = ServeCfg { max_batch, ..ServeCfg::default() };
    mk_loop_cfg(dir, dims, params, exec, &cfg, admission)
}

fn mk_loop_cfg(
    dir: &Path,
    dims: &ModelDims,
    params: &Arc<ParamSet>,
    exec: ExecCfg,
    cfg: &ServeCfg,
    admission: ServeAdmission,
) -> ServeLoop {
    let backend = build_backend(&exec, dir, dims, Arc::clone(params), cfg.max_batch).unwrap();
    ServeLoop::new(backend, dims, admission, cfg).unwrap()
}

fn mock_dims() -> ModelDims {
    ModelDims { name: "mock".into(), v: 32, p: 8, n: 8, k: 2, t: 16, w: 16, c: 8, eps: 1e-6 }
}

fn mk_mock_loop(cfg: &ServeCfg, admission: ServeAdmission) -> ServeLoop {
    let dims = mock_dims();
    let backend = Box::new(MockBackend::new(&dims, 8));
    ServeLoop::new(backend, &dims, admission, cfg).unwrap()
}

fn default_admission(dims: &ModelDims) -> ServeAdmission {
    ServeAdmission::new(dims, 80 << 30)
}

/// The mixed workload the stream-equivalence tests serve: staggered
/// arrivals, different lengths/temperatures (greedy included), so
/// admissions and evictions interleave mid-loop.
fn workload() -> Vec<Request> {
    vec![
        Request { prompt: vec![1, 2, 3], n_new: 10, temperature: 0.8, seed: 9, not_before_step: 0 },
        Request { prompt: vec![5, 4], n_new: 6, temperature: 0.0, seed: 1, not_before_step: 1 },
        Request { prompt: vec![7], n_new: 14, temperature: 1.3, seed: 33, not_before_step: 3 },
    ]
}

fn solo_for(
    dir: &Path,
    dims: &ModelDims,
    params: &ParamSet,
    reqs: &[Request],
) -> Vec<Vec<i32>> {
    let rt = Runtime::shared().unwrap();
    let arts = ArtifactSet::load(rt, dir).unwrap();
    reqs.iter()
        .map(|r| {
            generate::generate(
                &arts,
                dims,
                params,
                &r.prompt,
                r.n_new,
                r.temperature,
                &mut Rng::new(r.seed),
            )
            .unwrap()
        })
        .collect()
}

fn solo_streams(dir: &Path, dims: &ModelDims, params: &ParamSet) -> Vec<Vec<i32>> {
    solo_for(dir, dims, params, &workload())
}

/// Serve `reqs` through `sl` and return the per-session streams in sid
/// order.
fn run_streams(sl: &mut ServeLoop, reqs: &[Request]) -> Vec<Vec<i32>> {
    for r in reqs {
        sl.submit(r.clone()).unwrap();
    }
    sl.run_until_idle().unwrap();
    let mut fin = sl.take_finished();
    fin.sort_by_key(|f| f.sid);
    fin.into_iter().map(|f| f.tokens).collect()
}

fn scratch_dir(label: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("serve_test_{}_{label}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

#[test]
fn batched_serving_matches_solo_generate_with_mid_loop_arrivals_and_evictions() {
    let Some((dir, dims)) = tiny() else { return };
    let params = Arc::new(ParamSet::init(&dims, 13));
    // max_batch 2 < 3 sessions: the third arrival is deferred until an
    // eviction frees a slot — admissions and evictions both happen
    // mid-loop, and must not perturb anyone's stream.
    let mut sl = mk_loop(&dir, &dims, &params, ExecCfg::default(), 2, default_admission(&dims));
    for r in workload() {
        sl.submit(r).unwrap();
    }
    sl.run_until_idle().unwrap();
    let mut fin = sl.take_finished();
    fin.sort_by_key(|f| f.sid);
    let want = solo_streams(&dir, &dims, &params);
    assert_eq!(fin.len(), want.len());
    for (f, w) in fin.iter().zip(&want) {
        assert_eq!(f.tokens, *w, "session {} diverged from solo generate", f.sid);
    }
    assert_eq!(sl.metrics.admitted, 3);
    assert_eq!(sl.metrics.completed, 3);
    assert_eq!(sl.metrics.tokens_generated, 10 + 6 + 14);
    assert_eq!(sl.metrics.peak_sessions, 2, "batch cap must bound concurrency");
    assert!(sl.metrics.deferred > 0, "third arrival should have waited on a slot");
    assert_eq!(sl.active_sessions(), 0);
    assert_eq!(sl.queued(), 0);
}

#[test]
fn sim_and_threaded_executors_serve_identical_streams() {
    let Some((dir, dims)) = tiny() else { return };
    let params = Arc::new(ParamSet::init(&dims, 13));
    let mut streams = Vec::new();
    for exec in [
        ExecCfg { kind: ExecutorKind::Sim, ..ExecCfg::default() },
        ExecCfg { kind: ExecutorKind::Threaded, workers: 2, ..ExecCfg::default() },
    ] {
        let mut sl = mk_loop(&dir, &dims, &params, exec, 3, default_admission(&dims));
        assert_eq!(sl.executor_kind(), exec.kind);
        for r in workload() {
            sl.submit(r).unwrap();
        }
        sl.run_until_idle().unwrap();
        let mut fin = sl.take_finished();
        fin.sort_by_key(|f| f.sid);
        streams.push(fin.into_iter().map(|f| f.tokens).collect::<Vec<_>>());
    }
    assert_eq!(streams[0], streams[1], "sim and threaded streams must be bit-identical");
    assert_eq!(streams[0], solo_streams(&dir, &dims, &params));
}

#[test]
fn snapshot_restore_mid_sequence_reproduces_the_exact_stream() {
    let Some((dir, dims)) = tiny() else { return };
    let params = Arc::new(ParamSet::init(&dims, 13));
    let (prompt, n_new, temperature, seed) = (vec![2i32, 3, 4], 12usize, 0.9f32, 42u64);

    let mut sl = mk_loop(&dir, &dims, &params, ExecCfg::default(), 2, default_admission(&dims));
    let sid = sl
        .submit(Request {
            prompt: prompt.clone(),
            n_new,
            temperature,
            seed,
            not_before_step: 0,
        })
        .unwrap();
    // 3 prompt ticks + 5 decode ticks: pause mid-generation.
    for _ in 0..8 {
        assert!(sl.tick().unwrap());
    }
    let path = std::env::temp_dir().join(format!("serve_restore_{}.snap", std::process::id()));
    let prefix = sl.evict_to_snapshot(sid, &path).unwrap();
    assert_eq!(prefix.len(), 5, "expected to pause after 5 generated tokens");
    assert_eq!(sl.active_sessions(), 0);

    // Resume in a *fresh* loop (new backend, new PJRT client): only the
    // snapshot file carries the session.
    let mut sl2 = mk_loop(&dir, &dims, &params, ExecCfg::default(), 2, default_admission(&dims));
    sl2.restore(&path).unwrap();
    std::fs::remove_file(&path).ok();
    sl2.run_until_idle().unwrap();
    let fin = sl2.take_finished();
    assert_eq!(fin.len(), 1);
    let mut full = prefix;
    full.extend_from_slice(&fin[0].tokens);

    let rt = Runtime::shared().unwrap();
    let arts = ArtifactSet::load(rt, &dir).unwrap();
    let want = generate::generate(
        &arts,
        &dims,
        &params,
        &prompt,
        n_new,
        temperature,
        &mut Rng::new(seed),
    )
    .unwrap();
    assert_eq!(full, want, "snapshot→restore changed the token stream");
}

#[test]
fn admission_never_exceeds_the_memcost_hbm_cap() {
    let Some((dir, dims)) = tiny() else { return };
    let params = Arc::new(ParamSet::init(&dims, 13));
    // Cap sized for exactly two concurrent sessions (plus slack smaller
    // than a third): the memory gate — not the batch cap — binds.
    let base = ServeAdmission::new(&dims, 0);
    let per = base.session_bytes + base.step_bytes_per_session;
    let admission =
        ServeAdmission { hbm_bytes: base.model_bytes + 2 * per + per / 2, ..base };
    assert_eq!(admission.max_sessions(), 2);

    let mut sl = mk_loop(&dir, &dims, &params, ExecCfg::default(), 8, admission);
    for i in 0..5u64 {
        sl.submit(Request {
            prompt: vec![1 + i as i32],
            n_new: 4,
            temperature: 0.7,
            seed: 100 + i,
            not_before_step: 0,
        })
        .unwrap();
    }
    sl.run_until_idle().unwrap();
    assert_eq!(sl.metrics.completed, 5, "memory pressure must defer, not drop");
    assert_eq!(sl.metrics.peak_sessions, 2, "cap admits exactly two sessions");
    assert!(sl.metrics.deferred > 0);
    assert!(
        sl.admission().bytes_at(sl.metrics.peak_sessions as u64) <= sl.admission().hbm_bytes,
        "modeled bytes exceeded the HBM cap"
    );
}

#[test]
fn batched_abi_is_bit_identical_to_single_session_step_token() {
    let Some((dir, dims)) = tiny() else { return };
    let m = Manifest::load(&dir).unwrap();
    if !m.entries.contains_key("layer_step_batched") {
        eprintln!("SKIP: artifact set predates layer_step_batched (re-run `make artifacts`)");
        return;
    }
    let params = Arc::new(ParamSet::init(&dims, 13));
    let mut be = SimBackend::new(&dir, &dims, Arc::clone(&params)).unwrap();
    assert!(be.batch_width().is_some());

    let rt = Runtime::shared().unwrap();
    let arts = ArtifactSet::load(rt, &dir).unwrap();
    let mut solo: Vec<DecodeState> = (0..3)
        .map(|_| DecodeState::new(&arts, &params, &dims).unwrap())
        .collect();
    for sid in 0..3u64 {
        be.admit(sid, zeros_h(&dims)).unwrap();
    }
    let steps: [[i32; 3]; 4] = [[1, 5, 2], [3, 3, 60], [7, 0, 9], [2, 2, 2]];
    for toks in steps {
        let inputs: Vec<(u64, i32)> =
            toks.iter().enumerate().map(|(s, &t)| (s as u64, t)).collect();
        let (outs, cost) = be.step(&inputs).unwrap();
        assert!(cost.calls >= dims.k as u64);
        assert_eq!(outs.len(), 3);
        for (s, (sid, logits)) in outs.iter().enumerate() {
            assert_eq!(*sid, s as u64);
            let want =
                generate::step_token(&arts, &dims, &params, &mut solo[s], toks[s]).unwrap();
            let same = logits
                .data()
                .iter()
                .zip(want.data())
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "session {sid}: batched logits diverged from step_token");
        }
    }
    // Recurrent state also matches, bit for bit.
    for (sid, st) in solo.iter().enumerate() {
        let h = be.state(sid as u64).unwrap();
        for (k, (got, want)) in h.iter().zip(&st.h).enumerate() {
            assert_eq!(got.data(), want.data(), "state rows diverged at layer {k}");
        }
    }
}

#[test]
fn serve_rejects_bad_inputs() {
    let Some((dir, dims)) = tiny() else { return };
    let params = Arc::new(ParamSet::init(&dims, 13));
    let mut sl = mk_loop(&dir, &dims, &params, ExecCfg::default(), 2, default_admission(&dims));
    assert!(
        sl.submit(Request {
            prompt: vec![],
            n_new: 4,
            temperature: 0.5,
            seed: 0,
            not_before_step: 0
        })
        .is_err(),
        "empty prompts are rejected, as in generate"
    );
    let missing = std::env::temp_dir().join("definitely_missing.snap");
    assert!(sl.restore(&missing).is_err());
    assert!(sl.snapshot(999, &missing).is_err(), "snapshot of unknown session errors");
}

/// Long-document workload: prompts big enough that chunked prefill takes
/// several ragged chunks (13 tokens at chunk 5 → 5+5+3), mixed with a
/// short-prompt session and a late arrival.
fn long_doc_workload() -> Vec<Request> {
    vec![
        Request {
            prompt: (1..14).collect(),
            n_new: 6,
            temperature: 0.8,
            seed: 9,
            not_before_step: 0,
        },
        Request { prompt: vec![5, 4], n_new: 8, temperature: 0.0, seed: 1, not_before_step: 0 },
        Request {
            prompt: (3..12).collect(),
            n_new: 5,
            temperature: 1.1,
            seed: 33,
            not_before_step: 4,
        },
    ]
}

#[test]
fn chunked_prefill_is_bit_identical_across_executors() {
    let Some((dir, dims)) = tiny() else { return };
    let m = Manifest::load(&dir).unwrap();
    if !m.entries.contains_key("layer_prefill_chunk") {
        eprintln!("SKIP: artifact set predates layer_prefill_chunk (re-run `make artifacts`)");
        return;
    }
    let params = Arc::new(ParamSet::init(&dims, 13));
    let reqs = long_doc_workload();
    let want = solo_for(&dir, &dims, &params, &reqs);

    // chunk 5 deliberately divides no prompt length: the last chunk of
    // each prompt is ragged, exercising the scan-padding causality.
    for exec in [
        ExecCfg { kind: ExecutorKind::Sim, ..ExecCfg::default() },
        ExecCfg { kind: ExecutorKind::Threaded, workers: 2, ..ExecCfg::default() },
    ] {
        let cfg = ServeCfg { max_batch: 3, prefill_chunk: 5, ..ServeCfg::default() };
        let admission = ServeAdmission::with_prefill(&dims, 80 << 30, 5);
        let mut sl = mk_loop_cfg(&dir, &dims, &params, exec, &cfg, admission);
        let got = run_streams(&mut sl, &reqs);
        assert_eq!(got, want, "{}: chunked prefill changed a token stream", exec.kind);
        assert!(
            sl.counters.get("serve_prefill_chunks") > 0,
            "{}: prompts this long must have gone through the chunk path",
            exec.kind
        );
        assert!(sl.counters.get("serve_prefill_tokens") > 0);
        assert!(
            sl.trace.events().iter().any(|e| e.kind == TraceKind::Launch),
            "prefill chunks must emit Launch spans"
        );
    }
}

#[test]
fn lru_paging_under_pressure_is_bit_identical_to_never_paged() {
    let Some((dir, dims)) = tiny() else { return };
    let params = Arc::new(ParamSet::init(&dims, 13));
    let reqs: Vec<Request> = (0..5u64)
        .map(|i| Request {
            prompt: vec![1 + i as i32, 2],
            n_new: 4 + (i as usize % 3) * 2,
            temperature: if i == 2 { 0.0 } else { 0.9 },
            seed: 100 + i,
            not_before_step: i,
        })
        .collect();

    // Never-paged baseline: roomy cap, everything resident.
    let mut base =
        mk_loop(&dir, &dims, &params, ExecCfg::default(), 8, default_admission(&dims));
    let want = run_streams(&mut base, &reqs);

    // Pressure: cap admits exactly two sessions; with a page dir the loop
    // pages instead of deferring, so all five make progress via disk.
    let tight = ServeAdmission::new(&dims, 0);
    let per = tight.session_bytes + tight.step_bytes_per_session;
    for exec in [
        ExecCfg { kind: ExecutorKind::Sim, ..ExecCfg::default() },
        ExecCfg { kind: ExecutorKind::Threaded, workers: 2, ..ExecCfg::default() },
    ] {
        let pages = scratch_dir(&format!("paging_{}", exec.kind));
        let cfg = ServeCfg {
            max_batch: 8,
            page_dir: Some(pages.clone()),
            ..ServeCfg::default()
        };
        let admission =
            ServeAdmission { hbm_bytes: tight.model_bytes + 2 * per + per / 2, ..tight };
        assert_eq!(admission.max_sessions(), 2);
        let mut sl = mk_loop_cfg(&dir, &dims, &params, exec, &cfg, admission);
        let got = run_streams(&mut sl, &reqs);
        assert_eq!(got, want, "{}: paging changed a token stream", exec.kind);
        assert!(sl.counters.get("serve_pageouts") > 0, "pressure must have paged");
        assert!(sl.counters.get("serve_pageins") > 0, "paged sessions must restore");
        assert_eq!(sl.counters.get("serve_page_failures"), 0);
        assert_eq!(sl.paged_sessions(), 0);
        let spans: Vec<TraceKind> = sl.trace.events().iter().map(|e| e.kind).collect();
        assert!(spans.contains(&TraceKind::PageOut));
        assert!(spans.contains(&TraceKind::PageIn));
        // Retention: page files exist only while a session is on disk.
        let leftover: Vec<_> = std::fs::read_dir(&pages)
            .map(|rd| rd.filter_map(|e| e.ok()).collect())
            .unwrap_or_default();
        assert!(leftover.is_empty(), "{}: page files must be deleted on restore", exec.kind);
        std::fs::remove_dir_all(&pages).ok();
    }
}

/// Artifact-free paging roundtrip on the mock backend, so CI exercises
/// the LRU/page/restore scheduler even without `make artifacts`.
#[test]
fn mock_paging_roundtrip_is_bit_identical_and_cleans_up() {
    let dims = mock_dims();
    let reqs: Vec<Request> = (0..5u64)
        .map(|i| Request {
            prompt: vec![1 + i as i32, 7, 2],
            n_new: 5 + i as usize,
            temperature: 0.8,
            seed: 50 + i,
            not_before_step: 2 * i,
        })
        .collect();

    let roomy = ServeCfg { max_batch: 8, ..ServeCfg::default() };
    let mut base = mk_mock_loop(&roomy, ServeAdmission::new(&dims, u64::MAX));
    let want = run_streams(&mut base, &reqs);

    let tight = ServeAdmission::new(&dims, 0);
    let per = tight.session_bytes + tight.step_bytes_per_session;
    let pages = scratch_dir("mock_paging");
    let cfg = ServeCfg { max_batch: 8, page_dir: Some(pages.clone()), ..ServeCfg::default() };
    let admission =
        ServeAdmission { hbm_bytes: tight.model_bytes + 2 * per + per / 2, ..tight };
    assert_eq!(admission.max_sessions(), 2);
    let mut sl = mk_mock_loop(&cfg, admission);
    let got = run_streams(&mut sl, &reqs);
    assert_eq!(got, want, "paging changed a mock token stream");
    assert!(sl.counters.get("serve_pageouts") > 0);
    assert_eq!(sl.counters.get("serve_pageouts"), sl.counters.get("serve_pageins"));
    assert_eq!(sl.paged_sessions(), 0);
    let leftover: Vec<_> = std::fs::read_dir(&pages)
        .map(|rd| rd.filter_map(|e| e.ok()).collect())
        .unwrap_or_default();
    assert!(leftover.is_empty(), "page files must be deleted once sessions complete");
    std::fs::remove_dir_all(&pages).ok();
}

#[test]
fn corrupt_page_file_fails_loudly_without_poisoning_other_sessions() {
    let dims = mock_dims();
    let reqs = vec![
        Request { prompt: vec![1, 2], n_new: 12, temperature: 0.8, seed: 5, not_before_step: 0 },
        Request { prompt: vec![3, 4], n_new: 6, temperature: 0.0, seed: 6, not_before_step: 0 },
        Request { prompt: vec![5, 6], n_new: 6, temperature: 0.9, seed: 7, not_before_step: 4 },
    ];

    let roomy = ServeCfg { max_batch: 8, ..ServeCfg::default() };
    let mut base = mk_mock_loop(&roomy, ServeAdmission::new(&dims, u64::MAX));
    let want = run_streams(&mut base, &reqs);

    let tight = ServeAdmission::new(&dims, 0);
    let per = tight.session_bytes + tight.step_bytes_per_session;
    let pages = scratch_dir("corrupt_page");
    let cfg = ServeCfg { max_batch: 8, page_dir: Some(pages.clone()), ..ServeCfg::default() };
    let admission =
        ServeAdmission { hbm_bytes: tight.model_bytes + 2 * per + per / 2, ..tight };
    let mut sl = mk_mock_loop(&cfg, admission);
    for r in &reqs {
        sl.submit(r.clone()).unwrap();
    }
    // Session 2 arrives at step 4 and pages out the coldest resident
    // (sid 0: both candidates are past their prompts; sid breaks the tie).
    for _ in 0..5 {
        sl.tick().unwrap();
    }
    assert_eq!(sl.paged_sessions(), 1, "the step-4 arrival should have paged one session");
    let page = pages.join("session_0.page");
    assert!(page.exists(), "LRU victim should be sid 0");
    // Torn write: flip a byte mid-file; the CRC frame must catch it.
    let mut bytes = std::fs::read(&page).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xff;
    std::fs::write(&page, &bytes).unwrap();

    sl.run_until_idle().unwrap();
    let mut fin = sl.take_finished();
    fin.sort_by_key(|f| f.sid);
    assert_eq!(
        fin.iter().map(|f| f.sid).collect::<Vec<_>>(),
        vec![1, 2],
        "only the corrupted session may be lost"
    );
    for f in &fin {
        assert_eq!(f.tokens, want[f.sid as usize], "session {} was poisoned", f.sid);
    }
    assert_eq!(sl.counters.get("serve_page_failures"), 1);
    let failures = sl.page_failures();
    assert_eq!(failures.len(), 1);
    assert_eq!(failures[0].0, 0, "the failure must name the corrupted session");
    assert!(page.exists(), "a failed page file is kept on disk for postmortem");
    std::fs::remove_dir_all(&pages).ok();
}

#[test]
fn ttft_counts_queue_wait_for_deferred_arrivals() {
    let dims = mock_dims();
    let cfg = ServeCfg { max_batch: 1, ..ServeCfg::default() };
    let mut sl = mk_mock_loop(&cfg, ServeAdmission::new(&dims, u64::MAX));
    for seed in [11u64, 12] {
        sl.submit(Request {
            prompt: vec![1, 2, 3],
            n_new: 6,
            temperature: 0.7,
            seed,
            not_before_step: 0,
        })
        .unwrap();
    }
    sl.run_until_idle().unwrap();
    let mut fin = sl.take_finished();
    fin.sort_by_key(|f| f.sid);
    assert_eq!(fin.len(), 2);
    assert!(sl.metrics.deferred > 0, "batch cap 1 must defer the second arrival");
    let (ttft, post) = (fin[1].ttft_s.unwrap(), fin[1].ttft_post_admit_s.unwrap());
    assert!(
        ttft > post,
        "deferred session's TTFT ({ttft:.6}s) must include its queue wait \
         (post-admit {post:.6}s)"
    );
    // The first session was admitted on arrival: both figures describe
    // the same interval (modulo the admission bookkeeping between them).
    assert!(fin[0].ttft_s.unwrap() >= fin[0].ttft_post_admit_s.unwrap());
    assert_eq!(sl.metrics.first_token_s.len(), 2);
    assert_eq!(sl.metrics.ttft_post_admit.len(), 2);
}

#[test]
fn mid_stream_eviction_order_does_not_perturb_survivors() {
    let dims = mock_dims();
    let reqs: Vec<Request> = (0..3u64)
        .map(|i| Request {
            prompt: vec![1 + i as i32, 4],
            n_new: 12,
            temperature: 0.8,
            seed: 70 + i,
            not_before_step: 0,
        })
        .collect();

    let cfg = ServeCfg { max_batch: 8, ..ServeCfg::default() };
    let mut base = mk_mock_loop(&cfg, ServeAdmission::new(&dims, u64::MAX));
    let want = run_streams(&mut base, &reqs);

    // Evict sids 0 and 2 mid-stream, in both orders: the surviving
    // middle session's stream must be bit-identical to the quiet run.
    for (label, order) in [("ascending", [0u64, 2]), ("descending", [2u64, 0])] {
        let mut sl = mk_mock_loop(&cfg, ServeAdmission::new(&dims, u64::MAX));
        for r in &reqs {
            sl.submit(r.clone()).unwrap();
        }
        for _ in 0..6 {
            sl.tick().unwrap();
        }
        for sid in order {
            let snap = std::env::temp_dir()
                .join(format!("serve_evict_{}_{label}_{sid}.snap", std::process::id()));
            sl.evict_to_snapshot(sid, &snap).unwrap();
            std::fs::remove_file(&snap).ok();
        }
        sl.run_until_idle().unwrap();
        let fin = sl.take_finished();
        assert_eq!(fin.len(), 1, "{label}: only the survivor retires");
        assert_eq!(fin[0].sid, 1);
        assert_eq!(
            fin[0].tokens, want[1],
            "{label}: mid-stream evictions perturbed the survivor's stream"
        );
        assert!(sl.counters.get("serve_evictions") >= 2);
    }
}

/// Artifact-free chunked-prefill scheduling on the mock backend: the
/// chunk interleave must be a pure scheduling change.
#[test]
fn mock_chunked_prefill_matches_plain_decode() {
    let dims = mock_dims();
    let reqs: Vec<Request> = (0..3u64)
        .map(|i| Request {
            prompt: (0..11 + i as i32).map(|t| t % 9 + 1).collect(),
            n_new: 5,
            temperature: 0.8,
            seed: 40 + i,
            not_before_step: i,
        })
        .collect();

    let plain = ServeCfg { max_batch: 4, ..ServeCfg::default() };
    let mut base = mk_mock_loop(&plain, ServeAdmission::new(&dims, u64::MAX));
    let want = run_streams(&mut base, &reqs);

    let chunked = ServeCfg { max_batch: 4, prefill_chunk: 4, ..ServeCfg::default() };
    let mut sl = mk_mock_loop(&chunked, ServeAdmission::new(&dims, u64::MAX));
    let got = run_streams(&mut sl, &reqs);
    assert_eq!(got, want, "chunked prefill changed a mock token stream");
    assert!(sl.counters.get("serve_prefill_chunks") > 0);
    assert!(sl.counters.get("serve_prefill_tokens") >= 11);
}
