//! Serving correctness: the continuous-batching loop must be a pure
//! throughput transformation — every session's token stream is
//! bit-identical to running it alone through `generate::generate`,
//! regardless of batching, arrival interleaving, executor backend, or a
//! snapshot/restore cycle in the middle; and admission never exceeds the
//! memcost-modeled HBM cap.
//!
//! Artifact-gated (run `make artifacts` first); the batched-ABI test
//! additionally requires an artifact set that includes
//! `layer_step_batched` (regenerated sets do; pre-serving sets fall back
//! to the per-session path, which these stream tests still cover).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use adjoint_sharding::config::{ModelDims, ServeCfg};
use adjoint_sharding::exec::{ExecCfg, ExecutorKind};
use adjoint_sharding::generate::{self, DecodeState};
use adjoint_sharding::memcost::ServeAdmission;
use adjoint_sharding::model::ParamSet;
use adjoint_sharding::rng::Rng;
use adjoint_sharding::runtime::{ArtifactSet, Manifest, Runtime};
use adjoint_sharding::serve::{build_backend, Request, ServeLoop, SimBackend, StepBackend};
use adjoint_sharding::tensor::Tensor;

fn root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Artifact dir + dims, without opening a PJRT client (each backend
/// opens its own).
fn tiny() -> Option<(PathBuf, ModelDims)> {
    let dir = root().join("tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts`");
        return None;
    }
    let m = Manifest::load(&dir).unwrap();
    let dims = ModelDims::from_config_json(&m.raw_config).unwrap();
    Some((dir, dims))
}

fn zeros_h(dims: &ModelDims) -> Vec<Tensor> {
    (0..dims.k).map(|_| Tensor::zeros(&[dims.n])).collect()
}

fn mk_loop(
    dir: &Path,
    dims: &ModelDims,
    params: &Arc<ParamSet>,
    exec: ExecCfg,
    max_batch: usize,
    admission: ServeAdmission,
) -> ServeLoop {
    let backend = build_backend(&exec, dir, dims, Arc::clone(params), max_batch).unwrap();
    let cfg = ServeCfg { max_batch, snapshot_dir: None };
    ServeLoop::new(backend, dims, admission, &cfg).unwrap()
}

fn default_admission(dims: &ModelDims) -> ServeAdmission {
    ServeAdmission::new(dims, 80 << 30)
}

/// The mixed workload the stream-equivalence tests serve: staggered
/// arrivals, different lengths/temperatures (greedy included), so
/// admissions and evictions interleave mid-loop.
fn workload() -> Vec<Request> {
    vec![
        Request { prompt: vec![1, 2, 3], n_new: 10, temperature: 0.8, seed: 9, not_before_step: 0 },
        Request { prompt: vec![5, 4], n_new: 6, temperature: 0.0, seed: 1, not_before_step: 1 },
        Request { prompt: vec![7], n_new: 14, temperature: 1.3, seed: 33, not_before_step: 3 },
    ]
}

fn solo_streams(dir: &Path, dims: &ModelDims, params: &ParamSet) -> Vec<Vec<i32>> {
    let rt = Runtime::shared().unwrap();
    let arts = ArtifactSet::load(rt, dir).unwrap();
    workload()
        .iter()
        .map(|r| {
            generate::generate(
                &arts,
                dims,
                params,
                &r.prompt,
                r.n_new,
                r.temperature,
                &mut Rng::new(r.seed),
            )
            .unwrap()
        })
        .collect()
}

#[test]
fn batched_serving_matches_solo_generate_with_mid_loop_arrivals_and_evictions() {
    let Some((dir, dims)) = tiny() else { return };
    let params = Arc::new(ParamSet::init(&dims, 13));
    // max_batch 2 < 3 sessions: the third arrival is deferred until an
    // eviction frees a slot — admissions and evictions both happen
    // mid-loop, and must not perturb anyone's stream.
    let mut sl = mk_loop(&dir, &dims, &params, ExecCfg::default(), 2, default_admission(&dims));
    for r in workload() {
        sl.submit(r).unwrap();
    }
    sl.run_until_idle().unwrap();
    let mut fin = sl.take_finished();
    fin.sort_by_key(|f| f.sid);
    let want = solo_streams(&dir, &dims, &params);
    assert_eq!(fin.len(), want.len());
    for (f, w) in fin.iter().zip(&want) {
        assert_eq!(f.tokens, *w, "session {} diverged from solo generate", f.sid);
    }
    assert_eq!(sl.metrics.admitted, 3);
    assert_eq!(sl.metrics.completed, 3);
    assert_eq!(sl.metrics.tokens_generated, 10 + 6 + 14);
    assert_eq!(sl.metrics.peak_sessions, 2, "batch cap must bound concurrency");
    assert!(sl.metrics.deferred > 0, "third arrival should have waited on a slot");
    assert_eq!(sl.active_sessions(), 0);
    assert_eq!(sl.queued(), 0);
}

#[test]
fn sim_and_threaded_executors_serve_identical_streams() {
    let Some((dir, dims)) = tiny() else { return };
    let params = Arc::new(ParamSet::init(&dims, 13));
    let mut streams = Vec::new();
    for exec in [
        ExecCfg { kind: ExecutorKind::Sim, ..ExecCfg::default() },
        ExecCfg { kind: ExecutorKind::Threaded, workers: 2, ..ExecCfg::default() },
    ] {
        let mut sl = mk_loop(&dir, &dims, &params, exec, 3, default_admission(&dims));
        assert_eq!(sl.executor_kind(), exec.kind);
        for r in workload() {
            sl.submit(r).unwrap();
        }
        sl.run_until_idle().unwrap();
        let mut fin = sl.take_finished();
        fin.sort_by_key(|f| f.sid);
        streams.push(fin.into_iter().map(|f| f.tokens).collect::<Vec<_>>());
    }
    assert_eq!(streams[0], streams[1], "sim and threaded streams must be bit-identical");
    assert_eq!(streams[0], solo_streams(&dir, &dims, &params));
}

#[test]
fn snapshot_restore_mid_sequence_reproduces_the_exact_stream() {
    let Some((dir, dims)) = tiny() else { return };
    let params = Arc::new(ParamSet::init(&dims, 13));
    let (prompt, n_new, temperature, seed) = (vec![2i32, 3, 4], 12usize, 0.9f32, 42u64);

    let mut sl = mk_loop(&dir, &dims, &params, ExecCfg::default(), 2, default_admission(&dims));
    let sid = sl
        .submit(Request {
            prompt: prompt.clone(),
            n_new,
            temperature,
            seed,
            not_before_step: 0,
        })
        .unwrap();
    // 3 prompt ticks + 5 decode ticks: pause mid-generation.
    for _ in 0..8 {
        assert!(sl.tick().unwrap());
    }
    let path = std::env::temp_dir().join(format!("serve_restore_{}.snap", std::process::id()));
    let prefix = sl.evict_to_snapshot(sid, &path).unwrap();
    assert_eq!(prefix.len(), 5, "expected to pause after 5 generated tokens");
    assert_eq!(sl.active_sessions(), 0);

    // Resume in a *fresh* loop (new backend, new PJRT client): only the
    // snapshot file carries the session.
    let mut sl2 = mk_loop(&dir, &dims, &params, ExecCfg::default(), 2, default_admission(&dims));
    sl2.restore(&path).unwrap();
    std::fs::remove_file(&path).ok();
    sl2.run_until_idle().unwrap();
    let fin = sl2.take_finished();
    assert_eq!(fin.len(), 1);
    let mut full = prefix;
    full.extend_from_slice(&fin[0].tokens);

    let rt = Runtime::shared().unwrap();
    let arts = ArtifactSet::load(rt, &dir).unwrap();
    let want = generate::generate(
        &arts,
        &dims,
        &params,
        &prompt,
        n_new,
        temperature,
        &mut Rng::new(seed),
    )
    .unwrap();
    assert_eq!(full, want, "snapshot→restore changed the token stream");
}

#[test]
fn admission_never_exceeds_the_memcost_hbm_cap() {
    let Some((dir, dims)) = tiny() else { return };
    let params = Arc::new(ParamSet::init(&dims, 13));
    // Cap sized for exactly two concurrent sessions (plus slack smaller
    // than a third): the memory gate — not the batch cap — binds.
    let base = ServeAdmission::new(&dims, 0);
    let per = base.session_bytes + base.step_bytes_per_session;
    let admission =
        ServeAdmission { hbm_bytes: base.model_bytes + 2 * per + per / 2, ..base };
    assert_eq!(admission.max_sessions(), 2);

    let mut sl = mk_loop(&dir, &dims, &params, ExecCfg::default(), 8, admission);
    for i in 0..5u64 {
        sl.submit(Request {
            prompt: vec![1 + i as i32],
            n_new: 4,
            temperature: 0.7,
            seed: 100 + i,
            not_before_step: 0,
        })
        .unwrap();
    }
    sl.run_until_idle().unwrap();
    assert_eq!(sl.metrics.completed, 5, "memory pressure must defer, not drop");
    assert_eq!(sl.metrics.peak_sessions, 2, "cap admits exactly two sessions");
    assert!(sl.metrics.deferred > 0);
    assert!(
        sl.admission().bytes_at(sl.metrics.peak_sessions as u64) <= sl.admission().hbm_bytes,
        "modeled bytes exceeded the HBM cap"
    );
}

#[test]
fn batched_abi_is_bit_identical_to_single_session_step_token() {
    let Some((dir, dims)) = tiny() else { return };
    let m = Manifest::load(&dir).unwrap();
    if !m.entries.contains_key("layer_step_batched") {
        eprintln!("SKIP: artifact set predates layer_step_batched (re-run `make artifacts`)");
        return;
    }
    let params = Arc::new(ParamSet::init(&dims, 13));
    let mut be = SimBackend::new(&dir, &dims, Arc::clone(&params)).unwrap();
    assert!(be.batch_width().is_some());

    let rt = Runtime::shared().unwrap();
    let arts = ArtifactSet::load(rt, &dir).unwrap();
    let mut solo: Vec<DecodeState> = (0..3)
        .map(|_| DecodeState::new(&arts, &params, &dims).unwrap())
        .collect();
    for sid in 0..3u64 {
        be.admit(sid, zeros_h(&dims)).unwrap();
    }
    let steps: [[i32; 3]; 4] = [[1, 5, 2], [3, 3, 60], [7, 0, 9], [2, 2, 2]];
    for toks in steps {
        let inputs: Vec<(u64, i32)> =
            toks.iter().enumerate().map(|(s, &t)| (s as u64, t)).collect();
        let (outs, cost) = be.step(&inputs).unwrap();
        assert!(cost.calls >= dims.k as u64);
        assert_eq!(outs.len(), 3);
        for (s, (sid, logits)) in outs.iter().enumerate() {
            assert_eq!(*sid, s as u64);
            let want =
                generate::step_token(&arts, &dims, &params, &mut solo[s], toks[s]).unwrap();
            let same = logits
                .data()
                .iter()
                .zip(want.data())
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "session {sid}: batched logits diverged from step_token");
        }
    }
    // Recurrent state also matches, bit for bit.
    for (sid, st) in solo.iter().enumerate() {
        let h = be.state(sid as u64).unwrap();
        for (k, (got, want)) in h.iter().zip(&st.h).enumerate() {
            assert_eq!(got.data(), want.data(), "state rows diverged at layer {k}");
        }
    }
}

#[test]
fn serve_rejects_bad_inputs() {
    let Some((dir, dims)) = tiny() else { return };
    let params = Arc::new(ParamSet::init(&dims, 13));
    let mut sl = mk_loop(&dir, &dims, &params, ExecCfg::default(), 2, default_admission(&dims));
    assert!(
        sl.submit(Request {
            prompt: vec![],
            n_new: 4,
            temperature: 0.5,
            seed: 0,
            not_before_step: 0
        })
        .is_err(),
        "empty prompts are rejected, as in generate"
    );
    let missing = std::env::temp_dir().join("definitely_missing.snap");
    assert!(sl.restore(&missing).is_err());
    assert!(sl.snapshot(999, &missing).is_err(), "snapshot of unknown session errors");
}
