//! The paper's central correctness claim, measured end-to-end through the
//! Rust coordinator: adjoint sharding "computes equivalent gradients to
//! backpropagation".
//!
//! What the math supports (DESIGN.md §1) and what we assert:
//!  * Ω's gradient: exact in both modes (computed at the head either way).
//!  * Last layer (K−1): exact — no downstream layers drop terms.
//!  * Earlier layers: the residual-direct approximation — assert positive
//!    cosine alignment and record the measured gap (EXPERIMENTS.md).
//!  * Truncated window (tiny_trunc): still positively aligned.
//!  * Training: loss decreases on the Markov task in BOTH modes.

use std::path::{Path, PathBuf};

use adjoint_sharding::adjoint;
use adjoint_sharding::baselines;
use adjoint_sharding::config::{GradMode, ModelDims, RunConfig};
use adjoint_sharding::data::{Corpus, MarkovCorpus};
use adjoint_sharding::model::{GradSet, ParamSet};
use adjoint_sharding::pipeline;
use adjoint_sharding::runtime::{ArtifactSet, Runtime};
use adjoint_sharding::topology::Fleet;
use adjoint_sharding::train::Trainer;

fn root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have(name: &str) -> bool {
    root().join(name).join("manifest.json").exists()
}

/// Compute grads for one sample in both modes. Returns (adjoint, bptt, dims).
fn both_grads(config: &str, devices: usize) -> (GradSet, GradSet, ModelDims, f64, f64) {
    let rt = Runtime::shared().unwrap();
    let arts = ArtifactSet::load(rt, &root().join(config)).unwrap();
    let dims = ModelDims::from_config_json(&arts.manifest.raw_config).unwrap();
    let params = ParamSet::init(&dims, 5);
    let corpus = MarkovCorpus::new(dims.v, 9);
    let s = corpus.sample(0, dims.t);

    let mut fleet = Fleet::new(
        adjoint_sharding::config::TopologyCfg { devices, ..Default::default() },
        dims.k,
    )
    .unwrap();
    let fwd = pipeline::forward(&arts, &dims, &params, &mut fleet, &s.tokens, &s.targets).unwrap();
    let mut g_adj = GradSet::zeros(&dims);
    g_adj.omega.add_assign(&fwd.d_omega).unwrap();
    adjoint::backward(&arts, &dims, &params, &mut fleet, &mut g_adj).unwrap();

    let mut fleet2 = Fleet::new(Default::default(), dims.k).unwrap();
    let mut g_bptt = GradSet::zeros(&dims);
    let out = baselines::backward(
        &arts, &dims, &params, &mut fleet2, &s.tokens, &s.targets, &mut g_bptt,
    )
    .unwrap();

    (g_adj, g_bptt, dims, fwd.loss, out.loss)
}

fn flat(g: &adjoint_sharding::model::LayerParams) -> Vec<f32> {
    g.0.iter().flat_map(|t| t.data().iter().copied()).collect()
}

fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let dot: f64 = a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum();
    let na: f64 = a.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    dot / (na * nb + 1e-30)
}

#[test]
fn adjoint_matches_bptt_where_math_promises() {
    if !have("tiny") {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let (g_adj, g_bptt, dims, loss_a, loss_b) = both_grads("tiny", 1);

    // Same forward → same loss.
    assert!(
        ((loss_a - loss_b) / loss_b).abs() < 1e-4,
        "loss mismatch {loss_a} vs {loss_b}"
    );

    // Ω: exact.
    let rel = g_adj.omega.rel_l2(&g_bptt.omega).unwrap();
    assert!(rel < 1e-4, "dΩ rel err {rel}");

    // Last layer: exact (full window in 'tiny': W == T).
    let last = dims.k - 1;
    for (i, (ga, gb)) in g_adj.layers[last]
        .0
        .iter()
        .zip(&g_bptt.layers[last].0)
        .enumerate()
    {
        let rel = ga.rel_l2(gb).unwrap();
        assert!(
            rel < 5e-3,
            "last-layer grad {i} rel err {rel} (adjoint must be exact here)"
        );
    }

    // Earlier layers: residual-direct approximation — positive alignment.
    for k in 0..last {
        let c = cosine(&flat(&g_adj.layers[k]), &flat(&g_bptt.layers[k]));
        assert!(c > 0.2, "layer {k} cosine {c} — gradients misaligned");
    }
}

#[test]
fn multi_device_grads_match_single_device() {
    if !have("tiny") {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    // The sharding plan must not change the numbers: Υ=1 vs Υ=2.
    let (g1, _, dims, _, _) = both_grads("tiny", 1);
    let (g2, _, _, _, _) = both_grads("tiny", 2);
    for k in 0..dims.k {
        for (a, b) in g1.layers[k].0.iter().zip(&g2.layers[k].0) {
            let rel = a.rel_l2(b).unwrap();
            assert!(rel < 1e-5, "layer {k} differs across Υ: {rel}");
        }
    }
}

#[test]
fn truncated_window_grads_aligned() {
    if !have("tiny_trunc") {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let (g_adj, g_bptt, dims, _, _) = both_grads("tiny_trunc", 1);
    for k in 0..dims.k {
        let c = cosine(&flat(&g_adj.layers[k]), &flat(&g_bptt.layers[k]));
        assert!(c > 0.2, "layer {k} cosine {c} with truncated window");
    }
}

fn train_loss_drop(mode: GradMode) -> (f64, f64) {
    let rt = Runtime::shared().unwrap();
    let mut cfg = RunConfig::load(&root(), "tiny").unwrap();
    cfg.grad_mode = mode;
    cfg.optim.lr = 3e-3;
    cfg.log_every = usize::MAX;
    let corpus = Box::new(MarkovCorpus::new(cfg.dims.v, 7));
    let mut tr = Trainer::new(rt, cfg, corpus).unwrap();
    let mut first = 0.0;
    let mut n_steps = 0;
    for i in 0..40 {
        let r = tr.step().unwrap();
        if i == 0 {
            first = r.loss;
        }
        n_steps = i;
    }
    let _ = n_steps;
    let late = tr.recorder.mean_recent_loss(10);
    (first, late)
}

#[test]
fn training_reduces_loss_in_both_modes() {
    if !have("tiny") {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let (first_a, late_a) = train_loss_drop(GradMode::Adjoint);
    assert!(
        late_a < first_a - 0.2,
        "adjoint training did not learn: {first_a} -> {late_a}"
    );
    let (first_b, late_b) = train_loss_drop(GradMode::Bptt);
    assert!(
        late_b < first_b - 0.2,
        "bptt training did not learn: {first_b} -> {late_b}"
    );
}
