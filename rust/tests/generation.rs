//! Decode-path correctness: stepping the stack token-by-token with the
//! `layer_step` artifact must reproduce the full-sequence `layer_fwd`
//! training path exactly (same params, same tokens → same y_K rows), and
//! generation must be deterministic per seed.

use std::path::{Path, PathBuf};

use adjoint_sharding::config::ModelDims;
use adjoint_sharding::data::{Corpus, MarkovCorpus};
use adjoint_sharding::generate::{generate, sample, step_token, DecodeState};
use adjoint_sharding::model::ParamSet;
use adjoint_sharding::rng::Rng;
use adjoint_sharding::runtime::{fargs, ArtifactSet, Runtime};
use adjoint_sharding::tensor::{Arg, Tensor};

fn root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn load(config: &str) -> Option<(ArtifactSet, ModelDims)> {
    let dir = root().join(config);
    if !dir.join("manifest.json").exists() {
        return None;
    }
    let rt = Runtime::shared().unwrap();
    let arts = ArtifactSet::load(rt, &dir).unwrap();
    let dims = ModelDims::from_config_json(&arts.manifest.raw_config).unwrap();
    Some((arts, dims))
}

#[test]
fn stepwise_decode_matches_full_sequence_forward() {
    let Some((arts, dims)) = load("tiny") else {
        eprintln!("SKIP: run `make artifacts`");
        return;
    };
    let params = ParamSet::init(&dims, 13);
    let corpus = MarkovCorpus::new(dims.v, 5);
    let sample = corpus.sample(0, dims.t);

    // Training path: K × layer_fwd over the whole sequence.
    let layer_fwd = arts.entry("layer_fwd").unwrap();
    let y0 = params.embed_tokens(&sample.tokens).unwrap();
    let mut y = y0.clone();
    let mut xhat = y0.rmsnorm(dims.eps);
    let h0 = Tensor::zeros(&[dims.n]);
    for k in 0..dims.k {
        let mut args = fargs(params.layers[k].0.clone());
        args.push(Arg::F(xhat));
        args.push(Arg::F(y));
        args.push(Arg::F(h0.clone()));
        let outs = layer_fwd.run(&args).unwrap();
        let mut it = outs.into_iter();
        y = it.next().unwrap();
        xhat = it.next().unwrap();
    }

    // Decode path: token-by-token with carried state; compare logits rows
    // against y_K Ω from the training path.
    let mut state = DecodeState::zeros(&dims);
    for (t, &tok) in sample.tokens.data().iter().enumerate() {
        let logits = step_token(&arts, &dims, &params, &mut state, tok).unwrap();
        let y_row = y.slice_rows(t, 1).unwrap();
        let want = y_row.matmul(&params.omega).unwrap().reshape(&[dims.v]).unwrap();
        let rel = logits.rel_l2(&want).unwrap();
        assert!(rel < 1e-4, "token {t}: decode/train divergence rel {rel}");
    }
}

#[test]
fn generation_is_deterministic_and_in_vocab() {
    let Some((arts, dims)) = load("tiny") else {
        eprintln!("SKIP: run `make artifacts`");
        return;
    };
    let params = ParamSet::init(&dims, 13);
    let prompt = [1, 2, 3];
    let a = generate(&arts, &dims, &params, &prompt, 12, 0.8, &mut Rng::new(9)).unwrap();
    let b = generate(&arts, &dims, &params, &prompt, 12, 0.8, &mut Rng::new(9)).unwrap();
    let c = generate(&arts, &dims, &params, &prompt, 12, 0.8, &mut Rng::new(10)).unwrap();
    assert_eq!(a, b, "same seed must generate identically");
    assert_ne!(a, c, "different seeds should diverge (w.h.p.)");
    assert!(a.iter().all(|&t| (0..dims.v as i32).contains(&t)));
    assert_eq!(a.len(), 12);
}

// --- sampler properties (pure host; no artifacts needed) -------------------

#[test]
fn sample_argmax_equivalence_as_temperature_vanishes() {
    // Property: at T = 0 (and in the T → 0⁺ limit, where every non-max
    // softmax weight underflows to zero) sampling picks the argmax, for
    // any logits row and any RNG stream.
    for trial in 0..64u64 {
        let v = 2 + (trial % 9) as usize;
        let mut gen_rng = Rng::new(1000 + trial);
        let data: Vec<f32> = (0..v).map(|_| gen_rng.normal_f32() * 3.0).collect();
        let argmax = data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap();
        let logits = Tensor::new(vec![v], data).unwrap();
        assert_eq!(sample(&logits, 0.0, &mut Rng::new(trial)), argmax, "T=0, trial {trial}");
        assert_eq!(sample(&logits, -1.0, &mut Rng::new(trial)), argmax, "T<0 clamps to greedy");
        assert_eq!(
            sample(&logits, 1e-6, &mut Rng::new(trial)),
            argmax,
            "T→0⁺ limit, trial {trial}"
        );
    }
}

#[test]
fn sample_is_deterministic_per_seed_across_temperatures() {
    let logits = Tensor::new(vec![6], vec![0.3, -1.2, 2.0, 0.9, -0.4, 1.1]).unwrap();
    for &temp in &[0.0f32, 0.25, 0.8, 1.0, 2.5] {
        let draw = |seed: u64| -> Vec<i32> {
            let mut rng = Rng::new(seed);
            (0..32).map(|_| sample(&logits, temp, &mut rng)).collect()
        };
        let a = draw(7);
        assert_eq!(a, draw(7), "same seed must sample identically at T={temp}");
        assert!(a.iter().all(|&t| (0..6).contains(&t)), "out-of-vocab pick at T={temp}");
        if temp >= 0.8 {
            // Hot enough that 32 identical draws across independent
            // streams is ~1e-13 unlikely; colder temperatures are nearly
            // deterministic, where stream collisions are legitimate.
            assert_ne!(a, draw(8), "independent streams collided at T={temp}");
        }
    }
}

#[test]
fn generation_rejects_bad_inputs() {
    let Some((arts, dims)) = load("tiny") else {
        eprintln!("SKIP: run `make artifacts`");
        return;
    };
    let params = ParamSet::init(&dims, 13);
    assert!(generate(&arts, &dims, &params, &[], 4, 0.0, &mut Rng::new(0)).is_err());
    let mut state = DecodeState::zeros(&dims);
    assert!(step_token(&arts, &dims, &params, &mut state, dims.v as i32).is_err());
}
