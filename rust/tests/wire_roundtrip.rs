//! Wire-protocol roundtrips (ISSUE 6): `BatchGroup` dispatches and
//! 7-tensor gradient partials must cross the process boundary
//! byte-exactly — encode → decode → re-encode is the identity on bytes,
//! including f32 subnormals, negative zero, and the `usize::MAX`
//! cotangent key — and truncated or corrupt frames must be rejected as
//! clean errors, mirroring the snapshot-corruption units in `serve.rs`.
//! Host-only: no PJRT artifacts needed.

use std::io::Cursor;
use std::path::PathBuf;
use std::sync::Arc;

use adjoint_sharding::config::ModelDims;
use adjoint_sharding::exec::wire::{
    decode_done, decode_err, decode_hello, decode_job, encode_done, encode_err, encode_hello,
    encode_job, read_frame, write_frame, DeviceWorkMsg, DoneMsg, JobMsg, K_DONE, K_JOB, MAGIC,
    WIRE_VERSION,
};
use adjoint_sharding::obs::trace::{TraceEvent, TraceKind, COORD_LANE, NO_KEY};
use adjoint_sharding::sharding::{BatchGroup, WorkItem};
use adjoint_sharding::tensor::Tensor;
use adjoint_sharding::topology::ActKind;

fn dims() -> ModelDims {
    ModelDims { name: "wire".into(), v: 16, p: 8, n: 4, k: 2, t: 32, w: 8, c: 8, eps: 1e-6 }
}

/// Float patterns that round-trip only if the codec moves raw bits, not
/// values: negative zero, subnormals, extremes, and exact-precision
/// casualties.
fn nasty_floats() -> Vec<f32> {
    vec![
        0.0,
        -0.0,
        f32::MIN_POSITIVE / 2.0, // subnormal
        -f32::MIN_POSITIVE / 4.0,
        f32::MAX,
        f32::MIN,
        1.0 + f32::EPSILON,
        std::f32::consts::PI,
    ]
}

fn sample_job(kill: Option<u64>) -> JobMsg {
    let floats = nasty_floats();
    let acts = vec![
        (
            (0usize, ActKind::Xhat),
            Arc::new(Tensor::new(vec![2, 4], floats.clone()).unwrap()),
        ),
        (
            (1usize, ActKind::H),
            Arc::new(Tensor::new(vec![8], floats.clone()).unwrap()),
        ),
        // The replicated cotangent rides under the sentinel layer key.
        (
            (usize::MAX, ActKind::Cotangent),
            Arc::new(Tensor::new(vec![4, 2], floats.clone()).unwrap()),
        ),
    ];
    let items = vec![
        WorkItem { layer: 0, chunk_start: 0, chunk_len: 8 },
        WorkItem { layer: 0, chunk_start: 8, chunk_len: 8 },
        WorkItem { layer: 1, chunk_start: 0, chunk_len: 8 },
    ];
    JobMsg {
        dims: dims(),
        artifacts_dir: PathBuf::from("artifacts/tiny"),
        batch: 2,
        truncate: 3,
        items: items.clone(),
        devices: vec![DeviceWorkMsg {
            device: 1,
            items: vec![(0, items[0]), (1, items[1]), (2, items[2])],
            groups: vec![
                BatchGroup { layer: 0, ids: vec![0, 1] },
                BatchGroup { layer: 1, ids: vec![2] },
            ],
            acts,
            w_c: vec![(0, Arc::new(Tensor::new(vec![2, 4], floats).unwrap()))],
        }],
        kill,
        hang: None,
    }
}

fn sample_done() -> DoneMsg {
    let grads: Vec<Tensor> = (0..7)
        .map(|i| {
            let data = nasty_floats().iter().map(|f| f * (i + 1) as f32).collect();
            Tensor::new(vec![2, 4], data).unwrap()
        })
        .collect();
    DoneMsg {
        layer_grads: vec![(0, grads.clone()), (1, grads)],
        item_secs: vec![(0, 1.5e-6), (1, f64::MIN_POSITIVE), (2, 0.25)],
        wall_s: 0.125,
        overlap_s: 1e-9,
        calls: 3,
        died: false,
        executed: 3,
        // Wire v4: trace frames batched with the DONE reply. The sentinel
        // lane/key (usize::MAX) must survive the u64 crossing.
        trace: vec![
            TraceEvent::span_wall(1, TraceKind::Gather, 42, 1_000, NO_KEY, 0),
            TraceEvent::span_wall(1, TraceKind::Launch, 1_042, 9_000, 0, 0),
            TraceEvent::instant(COORD_LANE, TraceKind::StragglerWarn, NO_KEY, 7),
        ],
    }
}

#[test]
fn job_roundtrip_is_byte_exact() {
    for kill in [None, Some(0u64), Some(7)] {
        let job = sample_job(kill);
        let bytes = encode_job(&job).unwrap();
        let back = decode_job(&bytes).unwrap();
        // Byte-exactness: re-encoding the decoded message reproduces the
        // original payload bit for bit (tensor data crossed as raw bits).
        assert_eq!(encode_job(&back).unwrap(), bytes, "kill={kill:?}");
        // And the decoded structure matches field-wise.
        assert_eq!(back.kill, kill);
        assert_eq!(back.hang, job.hang);
        assert_eq!(back.batch, job.batch);
        assert_eq!(back.truncate, job.truncate);
        assert_eq!(back.items, job.items);
        assert_eq!(back.artifacts_dir, job.artifacts_dir);
        assert_eq!(back.dims.name, job.dims.name);
        assert_eq!(back.devices.len(), 1);
        let (d, b) = (&job.devices[0], &back.devices[0]);
        assert_eq!(b.device, d.device);
        assert_eq!(b.items, d.items);
        assert_eq!(b.groups, d.groups);
        assert_eq!(b.w_c.len(), d.w_c.len());
        for ((ka, ta), (kb, tb)) in d.acts.iter().zip(&b.acts) {
            assert_eq!(ka, kb);
            assert_eq!(ta.shape(), tb.shape());
            // Bit-compare, not float-compare: -0.0 == 0.0 would pass a
            // value comparison while corrupting the gradient bits.
            let bits_a: Vec<u32> = ta.data().iter().map(|f| f.to_bits()).collect();
            let bits_b: Vec<u32> = tb.data().iter().map(|f| f.to_bits()).collect();
            assert_eq!(bits_a, bits_b);
        }
    }
}

#[test]
fn done_roundtrip_is_byte_exact() {
    for done in [sample_done(), DoneMsg::dead(5)] {
        let bytes = encode_done(&done);
        let back = decode_done(&bytes).unwrap();
        assert_eq!(encode_done(&back), bytes);
        assert_eq!(back.died, done.died);
        assert_eq!(back.executed, done.executed);
        assert_eq!(back.calls, done.calls);
        // v4: trace events cross structurally intact, sentinels included.
        assert_eq!(back.trace, done.trace);
        for e in &back.trace {
            if e.lane == COORD_LANE {
                assert_eq!(e.key, NO_KEY, "sentinel lane/key must survive the wire");
            }
        }
        assert_eq!(back.layer_grads.len(), done.layer_grads.len());
        for ((la, ga), (lb, gb)) in done.layer_grads.iter().zip(&back.layer_grads) {
            assert_eq!(la, lb);
            assert_eq!(ga.len(), gb.len());
            for (ta, tb) in ga.iter().zip(gb) {
                let bits_a: Vec<u32> = ta.data().iter().map(|f| f.to_bits()).collect();
                let bits_b: Vec<u32> = tb.data().iter().map(|f| f.to_bits()).collect();
                assert_eq!(bits_a, bits_b);
            }
        }
    }
}

#[test]
fn hello_and_err_roundtrip_through_frames() {
    let mut buf = Vec::new();
    write_frame(&mut buf, K_JOB, &encode_hello(WIRE_VERSION)).unwrap();
    write_frame(&mut buf, K_DONE, &encode_err("lane 1 lost its runtime")).unwrap();
    let mut r = Cursor::new(buf);
    let (k1, p1) = read_frame(&mut r).unwrap().unwrap();
    assert_eq!(k1, K_JOB);
    assert_eq!(decode_hello(&p1).unwrap(), WIRE_VERSION);
    let (k2, p2) = read_frame(&mut r).unwrap().unwrap();
    assert_eq!(k2, K_DONE);
    assert_eq!(decode_err(&p2).unwrap(), "lane 1 lost its runtime");
    // Clean EOF at a frame boundary is Ok(None) — how a finished worker
    // hangs up — never an error.
    assert!(read_frame(&mut r).unwrap().is_none());
}

#[test]
fn truncated_frames_rejected_at_every_prefix() {
    let mut buf = Vec::new();
    write_frame(&mut buf, K_DONE, &encode_done(&sample_done())).unwrap();
    for cut in 0..buf.len() {
        let mut r = Cursor::new(&buf[..cut]);
        let got = read_frame(&mut r);
        if cut == 0 {
            // Zero bytes at a frame boundary: clean EOF.
            assert!(matches!(got, Ok(None)), "cut=0 must read as clean EOF");
        } else {
            // Any strict prefix is a torn frame: header or payload cut
            // mid-way must surface as an error, never a short read.
            assert!(got.is_err(), "cut={cut}/{} accepted a torn frame", buf.len());
        }
    }
    // The full buffer reads back whole.
    let mut r = Cursor::new(&buf[..]);
    let (kind, payload) = read_frame(&mut r).unwrap().unwrap();
    assert_eq!(kind, K_DONE);
    assert!(decode_done(&payload).is_ok());
}

#[test]
fn corrupt_frames_rejected() {
    // Bad magic: a stream that isn't ours at all.
    let mut bad = Vec::new();
    write_frame(&mut bad, K_DONE, b"xyz").unwrap();
    bad[0] ^= 0xFF;
    assert!(read_frame(&mut Cursor::new(&bad[..])).is_err());
    assert_ne!(bad[..4], MAGIC);

    // Absurd length: must be rejected *before* any allocation.
    let mut huge = Vec::new();
    huge.extend_from_slice(&MAGIC);
    huge.push(K_DONE);
    huge.extend_from_slice(&u64::MAX.to_le_bytes());
    assert!(read_frame(&mut Cursor::new(&huge[..])).is_err());
}

#[test]
fn corrupt_payloads_rejected() {
    // Every strict prefix of a JOB payload fails to decode: vectors are
    // length-prefixed and scalars fixed-width, so a cut always lands
    // inside some field — and the decoder bounds-checks every take.
    let bytes = encode_job(&sample_job(Some(3))).unwrap();
    for cut in 0..bytes.len() {
        assert!(decode_job(&bytes[..cut]).is_err(), "job prefix {cut}/{} decoded", bytes.len());
    }
    // Trailing garbage is rejected by exact-consumption, not ignored.
    let mut padded = bytes.clone();
    padded.push(0);
    assert!(decode_job(&padded).is_err(), "trailing byte accepted");

    let done_bytes = encode_done(&sample_done());
    for cut in 0..done_bytes.len() {
        assert!(decode_done(&done_bytes[..cut]).is_err(), "done prefix {cut} decoded");
    }
    let mut padded = done_bytes.clone();
    padded.extend_from_slice(&[0, 1, 2]);
    assert!(decode_done(&padded).is_err(), "trailing bytes accepted");
}
