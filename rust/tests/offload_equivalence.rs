//! Offload + truncation equivalence (ISSUE 8): the pinned-host
//! activation tier and `--truncate-window` must never change gradient
//! bits — spilling changes *where* bytes are accounted and *when* phases
//! run, never which items execute or in what order, and truncation's
//! surviving in-window terms are bit-identical to the full run's
//! corresponding partial sums.
//!
//! Host-side tests (tier transitions, spill-over-defer planning, §4.3
//! count identities) run everywhere; the PJRT sweeps skip with a message
//! when `make artifacts` hasn't run.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use adjoint_sharding::adjoint::{self, StagePool};
use adjoint_sharding::config::{ModelDims, SchedCfg, TopologyCfg};
use adjoint_sharding::data::{Corpus, MarkovCorpus};
use adjoint_sharding::exec::{Executor, ProcessExecutor, SimExecutor, ThreadedExecutor};
use adjoint_sharding::memcost::{self, MemModel};
use adjoint_sharding::model::{GradSet, ParamSet};
use adjoint_sharding::pipeline;
use adjoint_sharding::runtime::{ArtifactSet, Runtime};
use adjoint_sharding::schedule::{self, PolicyKind, SchedItem};
use adjoint_sharding::sharding::vjp_count_truncated;
use adjoint_sharding::tensor::Tensor;
use adjoint_sharding::topology::{ActKind, Fleet, Tier};

// ---------------------------------------------------------------------------
// Host-side: tier transitions are bit-exact and byte-conserving.
// ---------------------------------------------------------------------------

#[test]
fn spill_restore_roundtrip_is_bit_exact_and_conserves_bytes() {
    let mut c = TopologyCfg { devices: 1, ..Default::default() };
    c.offload = true;
    let mut f = Fleet::new(c, 2).unwrap();
    let d = &mut f.devices[0];
    let t = Tensor::new(vec![2, 4], vec![0.0, -0.0, 1.5, f32::MIN_POSITIVE / 2.0, -3.25, 7.0, 0.125, -0.5]).unwrap();
    let bits: Vec<u32> = t.data().iter().map(|x| x.to_bits()).collect();
    let bytes = t.size_bytes() as u64;
    d.put(0, ActKind::H, t);

    let (hbm0, host0) = (d.mem.live, d.host.live);
    assert_eq!(d.tier(0, ActKind::H), Some(Tier::Hbm));

    // Spill: bytes leave HBM, land on host, counters record the move.
    assert_eq!(d.spill(0, ActKind::H).unwrap(), bytes);
    assert_eq!(d.tier(0, ActKind::H), Some(Tier::Host));
    assert_eq!(d.mem.live, hbm0 - bytes);
    assert_eq!(d.host.live, host0 + bytes);
    assert_eq!(d.spilled_bytes, bytes);
    // Idempotent: re-spilling a host-resident key moves nothing.
    assert_eq!(d.spill(0, ActKind::H).unwrap(), 0);
    assert_eq!(d.spilled_bytes, bytes);

    // The data is bit-identical while spilled — the tier is an accounting
    // contract, never a lossy copy.
    let spilled_bits: Vec<u32> =
        d.get(0, ActKind::H).unwrap().data().iter().map(|x| x.to_bits()).collect();
    assert_eq!(spilled_bits, bits);

    // Restore: the exact inverse transition.
    assert_eq!(d.restore(0, ActKind::H).unwrap(), bytes);
    assert_eq!(d.tier(0, ActKind::H), Some(Tier::Hbm));
    assert_eq!(d.mem.live, hbm0);
    assert_eq!(d.host.live, host0);
    assert_eq!(d.restored_bytes, bytes);
    assert_eq!(d.restore(0, ActKind::H).unwrap(), 0);
    let back: Vec<u32> =
        d.get(0, ActKind::H).unwrap().data().iter().map(|x| x.to_bits()).collect();
    assert_eq!(back, bits);

    // Absent keys are hard errors, not silent no-ops.
    assert!(d.spill(7, ActKind::A).is_err());
    assert!(d.restore(7, ActKind::A).is_err());
}

// ---------------------------------------------------------------------------
// Host-side: the planner spills the coldest layer instead of stalling.
// ---------------------------------------------------------------------------

#[test]
fn planner_spills_instead_of_deferring_and_shortens_makespan() {
    // 4 equal items on one device, 2 slots, but the memory cap admits
    // only one 600-byte transient at a time: the defer-only plan
    // serializes (makespan 4), the offload plan pages out the one
    // resident layer (400 B of headroom) and runs two-wide (makespan 2).
    let items: Vec<SchedItem> = (0..4)
        .map(|i| SchedItem {
            id: i,
            device: 0,
            layer: 0,
            cost_s: 1.0,
            ready_at: 0.0,
            mem_bytes: 600,
        })
        .collect();
    let caps = vec![Some(1000u64)];
    let policy = PolicyKind::Fifo.policy();

    let plain = schedule::plan_backward(&items, None, 0.0, 1, 2, &caps, policy.as_ref()).unwrap();
    assert!((plain.schedule.makespan_s() - 4.0).abs() < 1e-9, "defer-only must serialize");
    assert_eq!(plain.schedule.spilled_bytes(), 0);

    let spillable: Vec<BTreeMap<usize, u64>> = vec![[(9usize, 400u64)].into_iter().collect()];
    let off = schedule::plan_backward_offload(
        &items, None, 0.0, 1, 2, &caps, policy.as_ref(), &spillable,
    )
    .unwrap();
    let spills: Vec<_> = off.schedule.spills().collect();
    assert_eq!(spills.len(), 1, "exactly one eviction buys the needed headroom");
    assert_eq!((spills[0].device, spills[0].layer, spills[0].bytes), (0, 9, 400));
    assert_eq!(off.schedule.spilled_bytes(), 400);
    assert!(
        (off.schedule.makespan_s() - 2.0).abs() < 1e-9,
        "spill-over-defer must run two-wide, got {}",
        off.schedule.makespan_s()
    );
    // Same item set either way — spilling never changes membership.
    assert_eq!(off.schedule.scheduled_items(), plain.schedule.scheduled_items());
}

// ---------------------------------------------------------------------------
// Host-side: §4.3 count identities + the offload memory frontier.
// ---------------------------------------------------------------------------

#[test]
fn truncated_unit_identity_holds_for_every_window() {
    // Σ over a layer's chunk items of vjp_units(W_eff, T) must equal
    // T + 2·vjp_count_truncated(T, W_eff) — the identity the end-to-end
    // sweep below measures through real executions.
    let dims = ModelDims {
        name: "trunc".into(),
        v: 16,
        p: 8,
        n: 4,
        k: 3,
        t: 48,
        w: 16,
        c: 8,
        eps: 1e-6,
    };
    for win in 0..=dims.w + 4 {
        let sched = SchedCfg { truncate_window: win, ..Default::default() };
        let w_eff = sched.window(&dims);
        let per_layer: u64 = adjoint_sharding::sharding::plan_chunks(1, dims.t, dims.c)
            .unwrap()
            .iter()
            .map(|it| it.vjp_units(w_eff, dims.t))
            .sum();
        assert_eq!(
            per_layer,
            dims.t as u64 + 2 * vjp_count_truncated(dims.t as u64, w_eff as u64),
            "window {win}"
        );
    }
}

#[test]
fn offload_widens_the_modeled_memory_frontier() {
    // Acceptance (ISSUE 8): under a capped HBM budget, the modeled max
    // trainable context strictly increases once stored activations may
    // page to host RAM — and a starved host tier gives the offload
    // frontier nothing to win with. Same 1.27B Fig-1 model the
    // `max-context` report prints.
    let (_, dims) = memcost::fig1_models().into_iter().last().unwrap();
    let m = MemModel::default();
    let hbm = 40u64 << 30;
    let hbm_only = m.max_context(&dims, 2, 8, hbm, true, 2048, 7);
    let offload = m.max_context_offload(&dims, 2, 8, hbm, 1100 << 30, 2048, 7);
    assert!(
        offload > hbm_only,
        "offload must widen the frontier: {offload} vs {hbm_only}"
    );
    let starved = m.max_context_offload(&dims, 2, 8, hbm, 0, 2048, 7);
    assert!(starved <= hbm_only, "no host budget, no win: {starved} vs {hbm_only}");
}

// ---------------------------------------------------------------------------
// PJRT sweeps — skip without artifacts.
// ---------------------------------------------------------------------------

fn root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have(name: &str) -> bool {
    root().join(name).join("manifest.json").exists()
}

fn process_executor(workers: usize) -> ProcessExecutor {
    ProcessExecutor::new(workers).with_program(PathBuf::from(env!("CARGO_BIN_EXE_adjsh")))
}

fn assert_grads_bit_identical(a: &GradSet, b: &GradSet, ctx: &str) {
    for (k, (la, lb)) in a.layers.iter().zip(&b.layers).enumerate() {
        for (i, (ta, tb)) in la.0.iter().zip(&lb.0).enumerate() {
            assert_eq!(ta.data(), tb.data(), "{ctx}: layer {k} grad {i} differs");
        }
    }
    assert_eq!(a.omega.data(), b.omega.data(), "{ctx}: dΩ differs");
}

fn grads_differ(a: &GradSet, b: &GradSet) -> bool {
    a.layers
        .iter()
        .zip(&b.layers)
        .any(|(la, lb)| la.0.iter().zip(&lb.0).any(|(ta, tb)| ta.data() != tb.data()))
}

/// Forward once into a fresh fleet under `topo`, then backward with
/// `exec` under `sched`; returns the grads, the phase output, and the
/// total bytes the fleet spilled (forward `make_room` + plan evictions).
fn run_once(
    config: &str,
    topo: TopologyCfg,
    sched: &SchedCfg,
    seed: u64,
    exec: &mut dyn Executor,
) -> (GradSet, adjoint_sharding::adjoint::AdjointOutput, u64) {
    let rt = Runtime::shared().unwrap();
    let arts = ArtifactSet::load(rt, &root().join(config)).unwrap();
    let dims = ModelDims::from_config_json(&arts.manifest.raw_config).unwrap();
    let params = ParamSet::init(&dims, seed);
    let corpus = MarkovCorpus::new(dims.v, seed ^ 0x0FF1);
    let s = corpus.sample(0, dims.t);
    let mut fleet = Fleet::new(topo, dims.k).unwrap();
    pipeline::forward(&arts, &dims, &params, &mut fleet, &s.tokens, &s.targets).unwrap();
    let mut grads = GradSet::zeros(&dims);
    let mut pool = StagePool::new();
    let out = adjoint::backward_pooled(
        &arts, &dims, &params, &mut fleet, &mut grads, sched, None, &mut pool, exec,
    )
    .unwrap();
    let spilled: u64 = fleet.devices.iter().map(|d| d.spilled_bytes).sum();
    (grads, out, spilled)
}

#[test]
fn forced_spill_gradients_bit_identical_across_executors() {
    if !have("tiny") {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let sched = SchedCfg::default();
    let uncapped = TopologyCfg { devices: 2, ..Default::default() };
    let (g_ref, o_ref, s_ref) =
        run_once("tiny", uncapped, &sched, 11, &mut SimExecutor::new());
    assert_eq!(s_ref, 0, "uncapped run must not spill");

    // A 1-byte HBM cap forces every stored layer out to the host tier as
    // soon as it lands — maximal paging pressure on every executor.
    let capped = TopologyCfg { devices: 2, offload: true, hbm_bytes: 1, ..Default::default() };
    let mut runs: Vec<(&'static str, Box<dyn Executor>)> = vec![
        ("sim", Box::new(SimExecutor::new())),
        ("threaded", Box::new(ThreadedExecutor::new(0))),
        ("process", Box::new(process_executor(0))),
    ];
    for (label, exec) in runs.iter_mut() {
        let (g, o, spilled) =
            run_once("tiny", capped.clone(), &sched, 11, exec.as_mut());
        assert!(spilled > 0, "{label}: forced-spill run must actually page out");
        assert_grads_bit_identical(&g, &g_ref, &format!("forced-spill {label}"));
        assert_eq!(o.vjp_units, o_ref.vjp_units, "{label}: vjp_units");
        assert_eq!(o.calls, o_ref.calls, "{label}: calls");
    }
}

#[test]
fn mid_phase_plan_evictions_stay_bit_identical_and_report_stats() {
    if !have("tiny") {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    // Reference: untouched fleet, no offload.
    let sched = SchedCfg::default();
    let (g_ref, ..) = run_once(
        "tiny",
        TopologyCfg { devices: 2, ..Default::default() },
        &sched,
        13,
        &mut SimExecutor::new(),
    );

    // Same forward, then tighten the budget *between* forward and
    // backward so the activations are all HBM-resident (nothing spilled
    // by make_room) and the stall lands on the backward planner: its
    // spill-over-defer branch must fire, the evictions must be committed
    // to the fleet, and the modeled D2H/H2D stats must be reported.
    let rt = Runtime::shared().unwrap();
    let arts = ArtifactSet::load(rt, &root().join("tiny")).unwrap();
    let dims = ModelDims::from_config_json(&arts.manifest.raw_config).unwrap();
    let params = ParamSet::init(&dims, 13);
    let corpus = MarkovCorpus::new(dims.v, 13 ^ 0x0FF1);
    let s = corpus.sample(0, dims.t);
    let mut fleet =
        Fleet::new(TopologyCfg { devices: 2, ..Default::default() }, dims.k).unwrap();
    pipeline::forward(&arts, &dims, &params, &mut fleet, &s.tokens, &s.targets).unwrap();

    let headroom = memcost::adjoint_single_transient_bytes(&dims) * 3 / 2;
    let max_live = fleet.devices.iter().map(|d| d.mem.live).max().unwrap();
    fleet.cfg.offload = true;
    fleet.cfg.hbm_bytes = max_live + headroom;

    let mut grads = GradSet::zeros(&dims);
    let mut pool = StagePool::new();
    let out = adjoint::backward_pooled(
        &arts,
        &dims,
        &params,
        &mut fleet,
        &mut grads,
        &sched,
        None,
        &mut pool,
        &mut SimExecutor::new(),
    )
    .unwrap();

    assert!(out.spilled_bytes > 0, "tight cap must trigger plan evictions");
    assert!(out.spill_s > 0.0, "modeled D2H time must be charged");
    // A restore is modeled iff the spilled layer still has pending work,
    // and every modeled restore is classified as prefetch hit or miss.
    assert!(
        (out.restore_s > 0.0) == (out.prefetch_hit + out.prefetch_miss > 0),
        "restores ({}) and prefetch accounting ({}+{}) must agree",
        out.restore_s,
        out.prefetch_hit,
        out.prefetch_miss
    );
    // The evictions were committed: those layers are host-resident now.
    let host_resident: u64 = fleet.devices.iter().map(|d| d.host.live).sum();
    assert!(host_resident > 0, "committed spills must land on the host tier");
    assert_grads_bit_identical(&grads, &g_ref, "mid-phase evictions");
}

#[test]
fn truncate_window_sweep_matches_paper_count_and_wide_window_is_noop() {
    if !have("tiny") {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let rt = Runtime::shared().unwrap();
    let arts = ArtifactSet::load(rt, &root().join("tiny")).unwrap();
    let dims = ModelDims::from_config_json(&arts.manifest.raw_config).unwrap();
    drop(arts);
    let topo = || TopologyCfg { devices: 2, ..Default::default() };

    let (g_full, o_full, _) = run_once(
        "tiny",
        topo(),
        &SchedCfg::default(),
        17,
        &mut SimExecutor::new(),
    );

    let mut prev_units = 0u64;
    for win in [1usize, 2, dims.w / 2, dims.w, dims.w + 100] {
        let sched = SchedCfg { truncate_window: win, ..Default::default() };
        let w_eff = sched.window(&dims);
        let (g, o, _) = run_once("tiny", topo(), &sched, 17, &mut SimExecutor::new());

        // Acceptance (ISSUE 8): measured units equal the §4.3 closed form
        // exactly — per layer T vjp_C's + 2·vjp_count_truncated(T, W).
        let expect = dims.k as u64
            * (dims.t as u64 + 2 * vjp_count_truncated(dims.t as u64, w_eff as u64));
        assert_eq!(o.vjp_units, expect, "window {win}: measured units vs closed form");
        assert!(o.vjp_units >= prev_units, "window {win}: units must be window-monotone");
        prev_units = o.vjp_units;

        if w_eff >= dims.w {
            // W ≥ w clips nothing: an exact no-op, bit for bit.
            assert_grads_bit_identical(&g, &g_full, &format!("window {win} ≥ W"));
            assert_eq!(o.vjp_units, o_full.vjp_units);
        } else if win <= 2 {
            // A tight window must actually drop out-of-window terms.
            assert!(grads_differ(&g, &g_full), "window {win}: truncation changed nothing");
        }
    }
}

#[test]
fn truncated_backward_bit_identical_across_executors() {
    if !have("tiny") {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let rt = Runtime::shared().unwrap();
    let arts = ArtifactSet::load(rt, &root().join("tiny")).unwrap();
    let dims = ModelDims::from_config_json(&arts.manifest.raw_config).unwrap();
    drop(arts);
    let win = (dims.w / 4).max(1);
    let sched = SchedCfg { truncate_window: win, ..Default::default() };
    let topo = || TopologyCfg { devices: 2, ..Default::default() };

    let (g_sim, o_sim, _) = run_once("tiny", topo(), &sched, 19, &mut SimExecutor::new());
    let (g_thr, o_thr, _) =
        run_once("tiny", topo(), &sched, 19, &mut ThreadedExecutor::new(0));
    let (g_proc, o_proc, _) =
        run_once("tiny", topo(), &sched, 19, &mut process_executor(0));

    assert_grads_bit_identical(&g_sim, &g_thr, "truncated sim vs threaded");
    assert_grads_bit_identical(&g_sim, &g_proc, "truncated sim vs process");
    assert_eq!(o_sim.vjp_units, o_thr.vjp_units);
    assert_eq!(o_sim.vjp_units, o_proc.vjp_units);
    assert_eq!(o_sim.calls, o_thr.calls);
    assert_eq!(o_sim.calls, o_proc.calls);
}

#[test]
fn trainer_with_offload_and_truncation_matches_across_executors() {
    if !have("tiny") {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    use adjoint_sharding::config::RunConfig;
    use adjoint_sharding::exec::ExecutorKind;
    use adjoint_sharding::train::Trainer;

    std::env::set_var("ADJSH_WORKER_BIN", env!("CARGO_BIN_EXE_adjsh"));

    // --offload with a starving HBM cap + --truncate-window together,
    // end to end through the trainer: whole optimization trajectories
    // must coincide across executors (identical grads → identical Adam
    // updates → identical next-step losses), and the forward-pass loss
    // is truncation-blind (backward-only change), so step-1 losses also
    // match the untruncated baseline below.
    let mut losses = Vec::new();
    for kind in ExecutorKind::ALL {
        let rt = Runtime::shared().unwrap();
        let mut cfg = RunConfig::load(&root(), "tiny").unwrap();
        cfg.topology.devices = 2.min(cfg.dims.k);
        cfg.topology.offload = true;
        cfg.topology.hbm_bytes = 1;
        cfg.sched.truncate_window = (cfg.dims.w / 4).max(1);
        cfg.exec.kind = kind;
        cfg.log_every = usize::MAX;
        let corpus = Box::new(MarkovCorpus::new(cfg.dims.v, 29));
        let mut tr = Trainer::new(rt, cfg, corpus).unwrap();
        let mut run_losses = Vec::new();
        for _ in 0..3 {
            run_losses.push(tr.step().unwrap().loss);
        }
        losses.push(run_losses);
    }
    for (i, kind) in ExecutorKind::ALL.iter().enumerate().skip(1) {
        assert_eq!(
            losses[0], losses[i],
            "offload+truncation trajectories diverged: sim vs {kind}"
        );
    }

    // Step 1 runs on identical params, and truncation touches only the
    // backward phase — its first forward loss equals the full-window one.
    let rt = Runtime::shared().unwrap();
    let mut cfg = RunConfig::load(&root(), "tiny").unwrap();
    cfg.topology.devices = 2.min(cfg.dims.k);
    cfg.log_every = usize::MAX;
    let corpus = Box::new(MarkovCorpus::new(cfg.dims.v, 29));
    let mut tr = Trainer::new(rt, cfg, corpus).unwrap();
    let full_first = tr.step().unwrap().loss;
    assert_eq!(losses[0][0], full_first, "truncation must not touch the forward pass");
}
