//! Randomized property tests on coordinator invariants (proptest is
//! unavailable offline; cases are generated from the crate's own seeded
//! RNG — every failure reports its seed for replay).

use adjoint_sharding::memcost::MemModel;
use adjoint_sharding::rng::Rng;
use adjoint_sharding::sharding::{
    assign_layers, plan_chunks, vjp_count_enumerated, vjp_count_full, vjp_count_truncated,
    WorkItem,
};
use adjoint_sharding::schedule::makespan_fifo;
use adjoint_sharding::tensor::Tensor;

const CASES: usize = 300;

#[test]
fn prop_layer_assignment_partition() {
    let mut rng = Rng::new(0xA55);
    for case in 0..CASES {
        let k = 1 + rng.below(200) as usize;
        let d = 1 + rng.below(k as u64) as usize;
        let a = assign_layers(k, d).unwrap_or_else(|e| panic!("case {case} (k={k},d={d}): {e}"));
        // Partition: every layer exactly once, devices contiguous, balance ≤ 1.
        let mut seen = vec![0u8; k];
        for (v, layers) in a.layers_of_device.iter().enumerate() {
            assert!(!layers.is_empty(), "case {case}: empty device {v} (k={k}, d={d})");
            for w in layers.windows(2) {
                assert_eq!(w[1], w[0] + 1, "case {case}: non-contiguous");
            }
            for &l in layers {
                seen[l] += 1;
                assert_eq!(a.device_of_layer[l], v, "case {case}: inverse mismatch");
            }
        }
        assert!(seen.iter().all(|&s| s == 1), "case {case}: not a partition");
        let sizes: Vec<usize> = a.layers_of_device.iter().map(|l| l.len()).collect();
        assert!(
            sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1,
            "case {case}: imbalance {sizes:?}"
        );
    }
}

#[test]
fn prop_chunk_plan_covers_tokens_once() {
    let mut rng = Rng::new(0xBEE);
    for case in 0..CASES {
        let c = 1 + rng.below(64) as usize;
        let chunks = 1 + rng.below(32) as usize;
        let t = c * chunks;
        let k = 1 + rng.below(8) as usize;
        let items = plan_chunks(k, t, c).unwrap();
        assert_eq!(items.len(), k * chunks, "case {case}");
        for layer in 0..k {
            let mut covered = vec![false; t];
            for it in items.iter().filter(|i| i.layer == layer) {
                for tok in it.chunk_start..it.chunk_start + it.chunk_len {
                    assert!(!covered[tok], "case {case}: token {tok} twice");
                    covered[tok] = true;
                }
            }
            assert!(covered.iter().all(|&x| x), "case {case}: gap in coverage");
        }
    }
}

#[test]
fn prop_vjp_closed_form_equals_enumeration() {
    let mut rng = Rng::new(0xCAB);
    for case in 0..CASES {
        let t = 1 + rng.below(3000);
        let tbar = 1 + rng.below(t);
        assert_eq!(
            vjp_count_truncated(t, tbar),
            vjp_count_enumerated(t, tbar),
            "case {case}: t={t} tbar={tbar}"
        );
        assert_eq!(vjp_count_truncated(t, t), vjp_count_full(t), "case {case}");
        // Monotone in the window.
        if tbar > 1 {
            assert!(
                vjp_count_truncated(t, tbar - 1) <= vjp_count_truncated(t, tbar),
                "case {case}: not monotone"
            );
        }
    }
}

#[test]
fn prop_work_item_units_partition_under_chunking() {
    let mut rng = Rng::new(0xD06);
    for case in 0..CASES {
        let c = 1 + rng.below(16) as usize;
        let chunks = 1 + rng.below(16) as usize;
        let t = c * chunks;
        let w = 1 + rng.below(t as u64) as usize;
        let whole = WorkItem { layer: 0, chunk_start: 0, chunk_len: t }.vjp_units(w, t);
        let parts: u64 = plan_chunks(1, t, c)
            .unwrap()
            .iter()
            .map(|it| it.vjp_units(w, t))
            .sum();
        assert_eq!(whole, parts, "case {case}: t={t} c={c} w={w}");
        // Cross-check against the closed form: Σ units = T (vjp_C) + 2·truncated.
        let closed = t as u64 + 2 * vjp_count_truncated(t as u64, w as u64);
        assert_eq!(whole, closed, "case {case}: closed-form mismatch");
    }
}

#[test]
fn prop_makespan_bounds() {
    let mut rng = Rng::new(0xF1E);
    for case in 0..CASES {
        let n = 1 + rng.below(40) as usize;
        let slots = 1 + rng.below(12) as usize;
        let times: Vec<f64> = (0..n).map(|_| rng.uniform() + 1e-3).collect();
        let m = makespan_fifo(&times, slots);
        let total: f64 = times.iter().sum();
        let max = times.iter().cloned().fold(0.0, f64::max);
        // Classic list-scheduling bounds.
        assert!(m >= max - 1e-12, "case {case}: below max item");
        assert!(m >= total / slots as f64 - 1e-9, "case {case}: below ideal");
        assert!(m <= total + 1e-9, "case {case}: above serial");
        // More slots never hurt.
        let m2 = makespan_fifo(&times, slots + 1);
        assert!(m2 <= m + 1e-9, "case {case}: slots made it worse");
    }
}

#[test]
fn prop_slice_rows_padded_consistent_with_slice_rows() {
    let mut rng = Rng::new(0x51C);
    for case in 0..CASES {
        let rows = 1 + rng.below(40) as usize;
        let cols = 1 + rng.below(12) as usize;
        let t = Tensor::randn(&[rows, cols], 1.0, &mut Rng::new(case as u64));
        let start = rng.below(rows as u64 + 10) as usize;
        let len = 1 + rng.below(20) as usize;
        let padded = t.slice_rows_padded(start, len).unwrap();
        assert_eq!(padded.shape(), &[len, cols]);
        let avail = rows.saturating_sub(start).min(len);
        if avail > 0 {
            let exact = t.slice_rows(start, avail).unwrap();
            assert_eq!(&padded.data()[..avail * cols], exact.data(), "case {case}");
        }
        assert!(
            padded.data()[avail * cols..].iter().all(|&x| x == 0.0),
            "case {case}: pad not zero"
        );
    }
}

#[test]
fn prop_memory_model_monotone() {
    let m = MemModel::default();
    let mut rng = Rng::new(0x3E3);
    let (_, d) = &adjoint_sharding::memcost::fig1_models()[2];
    for case in 0..100 {
        let t1 = 1 + rng.below(1 << 20);
        let t2 = t1 + 1 + rng.below(1 << 20);
        assert!(
            m.backprop(d, t2, 2, 1).total() >= m.backprop(d, t1, 2, 1).total(),
            "case {case}: bp not monotone in T"
        );
        let a1 = m.adjoint(d, t1, 2, 1, 2048, 2048.min(t1), 7).total();
        let a2 = m.adjoint(d, t2, 2, 1, 2048, 2048.min(t2), 7).total();
        assert!(a2 >= a1, "case {case}: adjoint not monotone in T");
        // Sharding across more devices never increases per-device memory.
        let s1 = m.adjoint(d, t1, 2, 1, 2048, 2048.min(t1), 7).total();
        let s4 = m.adjoint(d, t1, 2, 4, 2048, 2048.min(t1), 7).total();
        assert!(s4 <= s1, "case {case}: Υ=4 used more than Υ=1");
    }
}
