//! `cargo bench --bench hotpath` — micro-benchmarks of the training hot
//! path (the §Perf profile): per-entry PJRT execution latency, the adjoint
//! work-item gather (host slicing/padding), gradient accumulation, the
//! Adam update, and a whole training step in both grad modes.
//!
//! These are the numbers the performance pass iterates on
//! (EXPERIMENTS.md §Perf).

use std::path::Path;
use std::rc::Rc;

use adjoint_sharding::adjoint;
use adjoint_sharding::config::{GradMode, ModelDims, OptimCfg, RunConfig, TopologyCfg};
use adjoint_sharding::data::{Corpus, MarkovCorpus};
use adjoint_sharding::model::{GradSet, ParamSet};
use adjoint_sharding::optim::ShardedAdam;
use adjoint_sharding::pipeline;
use adjoint_sharding::runtime::{ArtifactSet, Runtime};
use adjoint_sharding::sharding::plan_chunks;
use adjoint_sharding::topology::Fleet;
use adjoint_sharding::train::Trainer;
use adjoint_sharding::util::bench::bench;

fn main() {
    let root = Path::new("artifacts");
    let config = "small";
    if !root.join(config).join("manifest.json").exists() {
        eprintln!("SKIP hotpath bench: artifacts/{config} missing — run `make artifacts`");
        return;
    }
    let rt = Rc::new(Runtime::cpu().expect("pjrt"));
    let arts = ArtifactSet::load(rt.clone(), &root.join(config)).expect("artifacts");
    let dims = ModelDims::from_config_json(&arts.manifest.raw_config).expect("dims");
    let params = ParamSet::init(&dims, 0);
    let corpus = MarkovCorpus::new(dims.v, 0);
    let sample = corpus.sample(0, dims.t);

    println!("== hotpath micro-benches ('{config}': K={} T={} W={} C={}) ==\n", dims.k, dims.t, dims.w, dims.c);

    // 1. Forward pipeline (Alg. 1).
    let mut fleet = Fleet::new(TopologyCfg::default(), dims.k).unwrap();
    let s = bench("forward_pipeline(Alg.1)", 3, 20, 1.0, || {
        for d in &mut fleet.devices {
            d.end_step();
        }
        pipeline::forward(&arts, &dims, &params, &mut fleet, &sample.tokens, &sample.targets)
            .unwrap()
            .loss
    });
    println!("{s}");

    // 2. One adjoint work-item: gather (host) vs execute (PJRT).
    let fwd = {
        for d in &mut fleet.devices {
            d.end_step();
        }
        pipeline::forward(&arts, &dims, &params, &mut fleet, &sample.tokens, &sample.targets)
            .unwrap()
    };
    let _ = fwd;
    let items = plan_chunks(dims.k, dims.t, dims.c).unwrap();
    let item = items[items.len() / 2];
    let s = bench("adjoint_gather(host slice+pad)", 3, 50, 1.0, || {
        adjoint::gather_item_args(&dims, &fleet, &params, &item).unwrap()
    });
    println!("{s}");
    let entry = arts.entry("layer_adjoint_grad").unwrap();
    let args = adjoint::gather_item_args(&dims, &fleet, &params, &item).unwrap();
    let s = bench("adjoint_item_execute(PJRT)", 3, 30, 1.0, || entry.run(&args).unwrap());
    println!("{s}");

    // 3. Full backward phase (Alg. 4).
    let mut grads = GradSet::zeros(&dims);
    let s = bench("adjoint_backward(Alg.4)", 2, 10, 1.0, || {
        adjoint::backward(&arts, &dims, &params, &mut fleet, &mut grads).unwrap().calls
    });
    println!("{s}");

    // 4. Optimizer update.
    let mut p2 = params.clone();
    let mut opt = ShardedAdam::new(&p2, &OptimCfg::default());
    let s = bench("sharded_adam_step", 3, 50, 1.0, || {
        let mut g = grads.clone();
        opt.step(&mut p2, &mut g, Some(1.0)).unwrap()
    });
    println!("{s}");

    // 5. Whole training steps, both modes.
    for (mode, label) in [(GradMode::Adjoint, "train_step(adjoint)"), (GradMode::Bptt, "train_step(bptt)")] {
        let rt2 = Rc::new(Runtime::cpu().expect("pjrt"));
        let mut cfg = RunConfig::load(root, config).unwrap();
        cfg.grad_mode = mode;
        cfg.log_every = usize::MAX;
        let mut tr = Trainer::new(rt2, cfg, Box::new(MarkovCorpus::new(dims.v, 0))).unwrap();
        let s = bench(label, 2, 10, 1.5, || tr.step().unwrap().loss);
        println!("{s}");
    }
}
