//! `cargo bench --bench hotpath` — micro-benchmarks of the training hot
//! path (the §Perf profile): the host-side staging ops (old owning vs new
//! zero-copy arena paths), the adjoint work-item gather, gradient
//! accumulation, the Adam update, and — when `make artifacts` has run —
//! per-entry PJRT execution latency and whole training steps in both grad
//! modes.
//!
//! Always writes machine-readable results to `BENCH_hotpath.json`
//! (EXPERIMENTS.md §Perf); the host-side section needs no artifacts, so
//! the perf trajectory of the coordinator itself is tracked on every
//! host.

use std::path::Path;

use adjoint_sharding::adjoint::{self, stage_slot, ItemStage};
use adjoint_sharding::config::{GradMode, ModelDims, OptimCfg, RunConfig, TopologyCfg};
use adjoint_sharding::data::{Corpus, MarkovCorpus};
use adjoint_sharding::model::{GradSet, LayerParams, ParamSet};
use adjoint_sharding::optim::ShardedAdam;
use adjoint_sharding::pipeline;
use adjoint_sharding::runtime::{ArtifactSet, Runtime};
use adjoint_sharding::sharding::plan_chunks;
use adjoint_sharding::tensor::Tensor;
use adjoint_sharding::topology::Fleet;
use adjoint_sharding::train::Trainer;
use adjoint_sharding::util::bench::{bench, write_json, BenchStats, Provenance};

/// Host-bench dims: big enough that per-item staging cost is visible,
/// small enough to iterate quickly.
fn host_dims() -> ModelDims {
    ModelDims {
        name: "hotpath-host".into(),
        v: 64,
        p: 32,
        n: 32,
        k: 4,
        t: 512,
        w: 64,
        c: 64,
        eps: 1e-6,
    }
}

fn host_section(results: &mut Vec<BenchStats>) {
    let dims = host_dims();
    let params = ParamSet::init(&dims, 0);
    let mut fleet = Fleet::new(TopologyCfg { devices: 2, ..Default::default() }, dims.k).unwrap();
    adjoint::put_synthetic_activations(&dims, &mut fleet, 7);
    let items = plan_chunks(dims.k, dims.t, dims.c).unwrap();
    let item = items[items.len() / 2];

    println!(
        "-- host-side staging (synthetic activations: K={} T={} W={} C={}) --",
        dims.k, dims.t, dims.w, dims.c
    );

    // Old owning gather vs new arena-backed gather.
    let s = bench("adjoint_gather(host slice+pad)", 3, 50, 1.0, || {
        adjoint::gather_item_args(&dims, &fleet, &params, &item).unwrap()
    });
    println!("{s}");
    results.push(s);

    let mut stage = ItemStage::new();
    adjoint::gather_item_args_into(&dims, &fleet, &item, &mut stage).unwrap(); // warm the arena
    let s = bench("adjoint_gather_into(arena, zero-alloc)", 3, 50, 1.0, || {
        adjoint::gather_item_args_into(&dims, &fleet, &item, &mut stage).unwrap();
        stage.view(stage_slot::V_EXT).len()
    });
    println!("{s}");
    results.push(s);

    // Tensor staging primitives: owning vs into-place.
    let big = Tensor::randn(&[dims.t, dims.p], 1.0, &mut adjoint_sharding::rng::Rng::new(1));
    let s = bench("slice_rows_padded(owning)", 3, 100, 0.5, || {
        big.slice_rows_padded(dims.t - dims.c, dims.c + dims.w).unwrap()
    });
    println!("{s}");
    results.push(s);
    let mut buf = vec![0.0f32; (dims.c + dims.w) * dims.p];
    let s = bench("slice_rows_padded_into(pooled)", 3, 100, 0.5, || {
        big.slice_rows_padded_into(dims.t - dims.c, dims.c + dims.w, &mut buf).unwrap();
        buf[0]
    });
    println!("{s}");
    results.push(s);

    let s = bench("rmsnorm(owning)", 3, 100, 0.5, || big.rmsnorm(dims.eps));
    println!("{s}");
    results.push(s);
    let mut norm_buf = Tensor::zeros(&[dims.t, dims.p]);
    let s = bench("rmsnorm_into(pooled)", 3, 100, 0.5, || {
        big.rmsnorm_into(dims.eps, &mut norm_buf).unwrap();
        norm_buf.data()[0]
    });
    println!("{s}");
    results.push(s);

    // Gradient accumulation from a preallocated output buffer set.
    let mut grads = GradSet::zeros(&dims);
    let outs: Vec<Tensor> = LayerParams::shapes(&dims)
        .iter()
        .map(|s| Tensor::ones(s))
        .collect();
    let s = bench("grad_accumulate_layer", 3, 200, 0.5, || {
        grads.accumulate_layer(item.layer, &outs).unwrap()
    });
    println!("{s}");
    results.push(s);

    // Optimizer update.
    let mut p2 = params.clone();
    let mut opt = ShardedAdam::new(&p2, &OptimCfg::default());
    let s = bench("sharded_adam_step", 3, 50, 1.0, || {
        let mut g = grads.clone();
        opt.step(&mut p2, &mut g, Some(1.0)).unwrap()
    });
    println!("{s}");
    results.push(s);
}

fn pjrt_section(root: &Path, config: &str, results: &mut Vec<BenchStats>) {
    let rt = Runtime::shared().expect("pjrt");
    let arts = ArtifactSet::load(rt.clone(), &root.join(config)).expect("artifacts");
    let dims = ModelDims::from_config_json(&arts.manifest.raw_config).expect("dims");
    let params = ParamSet::init(&dims, 0);
    let corpus = MarkovCorpus::new(dims.v, 0);
    let sample = corpus.sample(0, dims.t);

    println!(
        "\n-- PJRT hot path ('{config}': K={} T={} W={} C={}) --\n",
        dims.k, dims.t, dims.w, dims.c
    );

    // 1. Forward pipeline (Alg. 1).
    let mut fleet = Fleet::new(TopologyCfg::default(), dims.k).unwrap();
    let s = bench("forward_pipeline(Alg.1)", 3, 20, 1.0, || {
        for d in &mut fleet.devices {
            d.end_step();
        }
        pipeline::forward(&arts, &dims, &params, &mut fleet, &sample.tokens, &sample.targets)
            .unwrap()
            .loss
    });
    println!("{s}");
    results.push(s);

    // 2. One adjoint work-item execution (PJRT), old path.
    for d in &mut fleet.devices {
        d.end_step();
    }
    pipeline::forward(&arts, &dims, &params, &mut fleet, &sample.tokens, &sample.targets)
        .unwrap();
    let items = plan_chunks(dims.k, dims.t, dims.c).unwrap();
    let item = items[items.len() / 2];
    let entry = arts.entry("layer_adjoint_grad").unwrap();
    let args = adjoint::gather_item_args(&dims, &fleet, &params, &item).unwrap();
    let s = bench("adjoint_item_execute(PJRT)", 3, 30, 1.0, || entry.run(&args).unwrap());
    println!("{s}");
    results.push(s);

    // 3. Full backward phase (Alg. 4) through the pooled staging path.
    let mut grads = GradSet::zeros(&dims);
    let mut pool = adjoint::StagePool::new();
    let mut exec = adjoint_sharding::exec::SimExecutor::new();
    let s = bench("adjoint_backward(Alg.4, pooled)", 2, 10, 1.0, || {
        adjoint::backward_pooled(
            &arts,
            &dims,
            &params,
            &mut fleet,
            &mut grads,
            &Default::default(),
            None,
            &mut pool,
            &mut exec,
        )
        .unwrap()
        .calls
    });
    println!("{s}");
    results.push(s);
    println!(
        "   (stage-pool alloc events over whole bench: {}; const cache: {} staged / {} hits)",
        pool.alloc_events(),
        arts.const_cache().stagings(),
        arts.const_cache().hits()
    );

    // 4. Whole training steps, both modes.
    for (mode, label) in [
        (GradMode::Adjoint, "train_step(adjoint)"),
        (GradMode::Bptt, "train_step(bptt)"),
    ] {
        let rt2 = Runtime::shared().expect("pjrt");
        let mut cfg = RunConfig::load(root, config).unwrap();
        cfg.grad_mode = mode;
        cfg.log_every = usize::MAX;
        let mut tr = Trainer::new(rt2, cfg, Box::new(MarkovCorpus::new(dims.v, 0))).unwrap();
        let s = bench(label, 2, 10, 1.5, || tr.step().unwrap().loss);
        println!("{s}");
        results.push(s);
    }

    // 5. Dispatch amortization (ISSUE 5): one layer's first M chunk items
    // through the single-item loop (M dispatches + host accumulation) vs
    // one batched call (1 dispatch, on-device reduction). Same work per
    // iteration, so the mean ratio IS the per-group speedup; `adjsh bench
    // hotpath` renders the pair with a calls/s + speedup column.
    if arts.manifest.entries.contains_key("layer_adjoint_grad_batched") {
        use adjoint_sharding::runtime::{ArgRef, ConstKey};
        use adjoint_sharding::sharding::BatchGroup;

        let entry_b = arts.entry("layer_adjoint_grad_batched").unwrap();
        let m = adjoint_sharding::exec::batched_entry_width(&entry_b.spec).unwrap();
        let take = m.min(dims.num_chunks());
        let group = BatchGroup { layer: 0, ids: (0..take).collect() };
        let wc = arts
            .staged_const(ConstKey::LayerParam { layer: 0, field: 6 }, params.layers[0].w_c())
            .unwrap();

        let mut grads = GradSet::zeros(&dims);
        let mut stage = ItemStage::new();
        let mut outs: Vec<Tensor> = entry
            .spec
            .outputs
            .iter()
            .map(|s| Tensor::zeros(&s.shape))
            .collect();
        println!(
            "\n-- adjoint dispatch amortization ({take} items/group, batched entry M={m}) --\n"
        );
        let s = bench("adjoint_dispatch_single_item", 3, 20, 1.0, || {
            for id in 0..take {
                let item = items[id];
                adjoint::gather_item_args_into(&dims, &fleet, &item, &mut stage).unwrap();
                let args = [
                    ArgRef::C(wc.as_ref()),
                    ArgRef::F(stage.view(stage_slot::XHAT)),
                    ArgRef::F(stage.view(stage_slot::HPREV)),
                    ArgRef::F(stage.view(stage_slot::H)),
                    ArgRef::F(stage.view(stage_slot::A_EXT)),
                    ArgRef::F(stage.view(stage_slot::C_EXT)),
                    ArgRef::F(stage.view(stage_slot::V_EXT)),
                ];
                entry.run_timed_into(&args, &mut outs).unwrap();
                grads.accumulate_layer(0, &outs).unwrap();
            }
            grads.layers[0].0[0].data()[0]
        });
        println!("{s}");
        results.push(s);

        let dev0 = &fleet.devices[fleet.device_of_layer(0)];
        let s = bench("adjoint_dispatch_batched", 3, 20, 1.0, || {
            adjoint::gather_group_args_into_from(
                &dims, dev0, &items, &group, m, &mut stage,
            )
            .unwrap();
            let acc = &grads.layers[0].0;
            let args = [
                ArgRef::C(wc.as_ref()),
                ArgRef::F(stage.view(stage_slot::XHAT)),
                ArgRef::F(stage.view(stage_slot::HPREV)),
                ArgRef::F(stage.view(stage_slot::H)),
                ArgRef::F(stage.view(stage_slot::A_EXT)),
                ArgRef::F(stage.view(stage_slot::C_EXT)),
                ArgRef::F(stage.view(stage_slot::V_EXT)),
                ArgRef::F(acc[0].view().unwrap()),
                ArgRef::F(acc[1].view().unwrap()),
                ArgRef::F(acc[2].view().unwrap()),
                ArgRef::F(acc[3].view().unwrap()),
                ArgRef::F(acc[4].view().unwrap()),
                ArgRef::F(acc[5].view().unwrap()),
                ArgRef::F(acc[6].view().unwrap()),
            ];
            entry_b.run_timed_into(&args, &mut outs).unwrap();
            outs[0].data()[0]
        });
        println!("{s}");
        results.push(s);
        println!("   ({take} PJRT dispatches/group amortized to 1 by the batched entry)");
    }

    // Per-entry latency spread: min = steady state, max = cold first call.
    for (name, st) in arts.all_stats() {
        println!(
            "entry {:<20} calls {:>6}  mean {}  min {}  max {}",
            name,
            st.calls,
            adjoint_sharding::util::bench::fmt_dur(st.mean_s()),
            adjoint_sharding::util::bench::fmt_dur(st.min_s()),
            adjoint_sharding::util::bench::fmt_dur(st.max_s()),
        );
    }
}

fn main() {
    let root = Path::new("artifacts");
    let config = "small";
    let have_artifacts = root.join(config).join("manifest.json").exists();

    println!("== hotpath micro-benches ==\n");
    let mut results: Vec<BenchStats> = Vec::new();
    host_section(&mut results);
    let note = if have_artifacts {
        host_note("host + PJRT sections")
    } else {
        eprintln!(
            "\nSKIP PJRT section: artifacts/{config} missing — run `make artifacts` \
             (host-side staging benches above ran without it)"
        );
        host_note("host section only; artifacts missing")
    };
    if have_artifacts {
        pjrt_section(root, config, &mut results);
    }

    let out = Path::new("BENCH_hotpath.json");
    let prov = Provenance::collect(&host_note("hotpath"), 0, &note);
    write_json(out, "hotpath", false, &note, &prov, &results).expect("writing bench json");
    println!("\nwrote {}", out.display());
}

fn host_note(scope: &str) -> String {
    format!("{scope}; host dims K=4 T=512 W=64 C=64")
}
