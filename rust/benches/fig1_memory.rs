//! `cargo bench --bench fig1_memory` — regenerates paper Fig. 1:
//! training memory vs model size (32M…1.27B), backprop vs adjoint
//! sharding, plus the measured CPU-scale calibration runs.
//!
//! Same generator as `adjsh bench fig1` (rust/src/reports).

use adjoint_sharding::reports;
use adjoint_sharding::util::cli::Cli;

fn main() {
    // cargo bench passes --bench; ignore harness flags.
    let mut cli = Cli::parse(
        std::env::args()
            .skip(1)
            .filter(|a| a != "--bench" && !a.starts_with("--bench=")),
    )
    .expect("cli");
    if let Err(e) = reports::fig1(&mut cli) {
        eprintln!("fig1 bench failed: {e:#}");
        std::process::exit(1);
    }
}
