//! `cargo bench --bench table1_vjp` — regenerates paper Table 1 (per-VJP
//! memory & FLOPs for the unstructured / diagonal / scalar SSM families)
//! plus the §4.5 worked example, with measured probe timings on this host.

use adjoint_sharding::reports;
use adjoint_sharding::util::cli::Cli;

fn main() {
    let mut cli = Cli::parse(
        std::env::args()
            .skip(1)
            .filter(|a| a != "--bench" && !a.starts_with("--bench=")),
    )
    .expect("cli");
    if let Err(e) = reports::table1(&mut cli) {
        eprintln!("table1 bench failed: {e:#}");
        std::process::exit(1);
    }
}
