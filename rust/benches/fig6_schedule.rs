//! `cargo bench --bench fig6_schedule` — the Fig. 6 companion report:
//! virtual backward-phase makespans under the event-driven scheduler,
//! fifo vs lpt vs layer-major dispatch, sequential (distributed Alg. 4)
//! vs overlapped (paralleled Alg. 4, released against the
//! chunked-pipeline forward model), with memory-aware admission against
//! the per-device HBM cap. Asserts the acceptance property: the
//! overlapped step never loses to the sequential one.
//!
//! Same generator as `adjsh bench schedule` (rust/src/reports).

use adjoint_sharding::reports;
use adjoint_sharding::util::cli::Cli;

fn main() {
    // cargo bench passes --bench; ignore harness flags.
    let mut cli = Cli::parse(
        std::env::args()
            .skip(1)
            .filter(|a| a != "--bench" && !a.starts_with("--bench=")),
    )
    .expect("cli");
    if let Err(e) = reports::fig6_schedule(&mut cli) {
        eprintln!("fig6_schedule bench failed: {e:#}");
        std::process::exit(1);
    }
}
