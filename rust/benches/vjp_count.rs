//! `cargo bench --bench vjp_count` — regenerates the paper's §4.3 VJP-count
//! claims (64% reduction at T=10K, T̄=2000), cross-checking closed forms
//! against literal enumeration, then the max-context memory-budget sweep
//! (abstract: 35K → >100K on five P4 instances) and the T̄ ablation.

use adjoint_sharding::reports;
use adjoint_sharding::util::cli::Cli;

fn main() {
    let args: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| a != "--bench" && !a.starts_with("--bench="))
        .collect();
    for gen in [
        reports::vjp_count as fn(&mut Cli) -> anyhow::Result<()>,
        reports::max_context,
        reports::tbar_sweep,
    ] {
        let mut cli = Cli::parse(args.clone()).expect("cli");
        if let Err(e) = gen(&mut cli) {
            eprintln!("vjp_count bench failed: {e:#}");
            std::process::exit(1);
        }
        println!();
    }
}
