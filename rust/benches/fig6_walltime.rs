//! `cargo bench --bench fig6_walltime` — regenerates paper Fig. 6:
//! training days/epoch vs context length for backprop, full adjoint
//! sharding, and truncated adjoint sharding (100-layer model, T̄ = 2000,
//! paper's 280× parallel-speedup assumption), with the per-VJP constant
//! calibrated from the Table-1 probe on this host.

use adjoint_sharding::reports;
use adjoint_sharding::util::cli::Cli;

fn main() {
    let mut cli = Cli::parse(
        std::env::args()
            .skip(1)
            .filter(|a| a != "--bench" && !a.starts_with("--bench=")),
    )
    .expect("cli");
    if let Err(e) = reports::fig6(&mut cli) {
        eprintln!("fig6 bench failed: {e:#}");
        std::process::exit(1);
    }
}
