//! `cargo bench --bench offload` — the §Memory-Frontier profile
//! (EXPERIMENTS.md): tier-transition cost (spill + restore roundtrip),
//! truncated vs full-window staging, offload-aware planning overhead,
//! and — when `make artifacts` has run — whole training steps under
//! forced paging and truncation vs the untouched baseline.
//!
//! Always writes machine-readable results to `BENCH_offload.json`
//! (placeholder-aware: `adjsh bench offload` refuses files with no
//! measured rows). The host-side section needs no artifacts.

use std::collections::BTreeMap;
use std::path::Path;

use adjoint_sharding::adjoint::{self, ItemStage};
use adjoint_sharding::config::{GradMode, ModelDims, RunConfig, TopologyCfg};
use adjoint_sharding::data::MarkovCorpus;
use adjoint_sharding::runtime::Runtime;
use adjoint_sharding::schedule::{self, PolicyKind, SchedItem};
use adjoint_sharding::sharding::plan_chunks;
use adjoint_sharding::topology::{ActKind, Fleet};
use adjoint_sharding::train::Trainer;
use adjoint_sharding::util::bench::{bench, write_json, BenchStats, Provenance};

/// Same host-bench dims as `hotpath.rs`, so the two profiles compose.
fn host_dims() -> ModelDims {
    ModelDims {
        name: "offload-host".into(),
        v: 64,
        p: 32,
        n: 32,
        k: 4,
        t: 512,
        w: 64,
        c: 64,
        eps: 1e-6,
    }
}

fn host_section(results: &mut Vec<BenchStats>) {
    let dims = host_dims();
    let topo = TopologyCfg { devices: 2, offload: true, ..Default::default() };
    let mut fleet = Fleet::new(topo, dims.k).unwrap();
    adjoint::put_synthetic_activations(&dims, &mut fleet, 7);
    let items = plan_chunks(dims.k, dims.t, dims.c).unwrap();
    let item = items[items.len() / 2];

    println!(
        "-- tier transitions + truncated staging (K={} T={} W={} C={}) --",
        dims.k, dims.t, dims.w, dims.c
    );

    // One whole layer out to the host tier and back: the accounting cost
    // a mid-phase eviction pays on the coordinator (the simulated D2H/H2D
    // wire time is modeled separately by `memcost::OffloadModel`).
    let s = bench("spill_restore_roundtrip(layer)", 3, 100, 0.5, || {
        let d = &mut fleet.devices[0];
        let moved = d.spill_layer(0);
        for kind in [ActKind::Xhat, ActKind::H, ActKind::A, ActKind::C] {
            d.restore(0, kind).unwrap();
        }
        moved
    });
    println!("{s}");
    results.push(s);

    // Truncated gather vs full-window gather: the `--truncate-window`
    // staging path adds only a tail zero-fill on V_EXT.
    let dev = fleet.device_of_layer(item.layer);
    let mut stage = ItemStage::new();
    adjoint::gather_item_args_into_from_truncated(
        &dims,
        &fleet.devices[dev],
        &item,
        dims.w,
        &mut stage,
    )
    .unwrap(); // warm the arena
    let s = bench("gather_into(full window)", 3, 50, 0.5, || {
        adjoint::gather_item_args_into_from_truncated(
            &dims,
            &fleet.devices[dev],
            &item,
            dims.w,
            &mut stage,
        )
        .unwrap();
        stage.view(adjoint::stage_slot::V_EXT).len()
    });
    println!("{s}");
    results.push(s);
    let s = bench("gather_into(truncated W/4)", 3, 50, 0.5, || {
        adjoint::gather_item_args_into_from_truncated(
            &dims,
            &fleet.devices[dev],
            &item,
            dims.w / 4,
            &mut stage,
        )
        .unwrap();
        stage.view(adjoint::stage_slot::V_EXT).len()
    });
    println!("{s}");
    results.push(s);

    // Planning overhead of spill-over-defer admission: same 256-item
    // phase, defer-only vs with an evictable pool under a tight cap.
    let sched_items: Vec<SchedItem> = (0..256)
        .map(|i| SchedItem {
            id: i,
            device: i % 2,
            layer: i / 32,
            cost_s: 1e-3,
            ready_at: 0.0,
            mem_bytes: 600,
        })
        .collect();
    let caps = vec![Some(1000u64); 2];
    let spillable: Vec<BTreeMap<usize, u64>> = (0..2)
        .map(|_| (0..8usize).map(|l| (l, 200u64)).collect())
        .collect();
    let policy = PolicyKind::Fifo.policy();
    let s = bench("plan_backward(defer-only)", 3, 50, 0.5, || {
        schedule::plan_backward(&sched_items, None, 0.0, 2, 7, &caps, policy.as_ref())
            .unwrap()
            .schedule
            .scheduled_items()
    });
    println!("{s}");
    results.push(s);
    let s = bench("plan_backward_offload(spill-coldest)", 3, 50, 0.5, || {
        schedule::plan_backward_offload(
            &sched_items,
            None,
            0.0,
            2,
            7,
            &caps,
            policy.as_ref(),
            &spillable,
        )
        .unwrap()
        .schedule
        .spilled_bytes()
    });
    println!("{s}");
    results.push(s);
}

fn pjrt_section(root: &Path, config: &str, results: &mut Vec<BenchStats>) {
    println!("\n-- whole training steps ('{config}') --\n");
    // Baseline, forced paging (1-byte HBM cap spills every stored layer),
    // and a W/4 truncation window. Wall time should be near-flat across
    // the three: spills are tier flips on the accountant, and truncation
    // keeps the kernel shapes (the slab is zero-tailed, not shrunk) — the
    // win truncation buys is *modeled* VJP units, which `adjsh bench
    // tbar-sweep` reports.
    let variants: [(&str, Box<dyn Fn(&mut RunConfig)>); 3] = [
        ("train_step(adjoint)", Box::new(|_: &mut RunConfig| {})),
        (
            "train_step(adjoint, forced-spill)",
            Box::new(|cfg: &mut RunConfig| {
                cfg.topology.offload = true;
                cfg.topology.hbm_bytes = 1;
            }),
        ),
        (
            "train_step(adjoint, truncate W/4)",
            Box::new(|cfg: &mut RunConfig| {
                cfg.sched.truncate_window = (cfg.dims.w / 4).max(1);
            }),
        ),
    ];
    for (label, tweak) in variants {
        let rt = Runtime::shared().expect("pjrt");
        let mut cfg = RunConfig::load(root, config).unwrap();
        cfg.grad_mode = GradMode::Adjoint;
        cfg.log_every = usize::MAX;
        tweak(&mut cfg);
        let v = cfg.dims.v;
        let mut tr = Trainer::new(rt, cfg, Box::new(MarkovCorpus::new(v, 0))).unwrap();
        let s = bench(label, 2, 10, 1.5, || tr.step().unwrap().loss);
        println!("{s}");
        results.push(s);
    }
}

fn main() {
    let root = Path::new("artifacts");
    let config = "small";
    let have_artifacts = root.join(config).join("manifest.json").exists();

    println!("== offload / truncation micro-benches ==\n");
    let mut results: Vec<BenchStats> = Vec::new();
    host_section(&mut results);
    let note = if have_artifacts {
        "host + PJRT sections; host dims K=4 T=512 W=64 C=64".to_string()
    } else {
        eprintln!(
            "\nSKIP PJRT section: artifacts/{config} missing — run `make artifacts` \
             (tier-transition benches above ran without it)"
        );
        "host section only; artifacts missing; host dims K=4 T=512 W=64 C=64".to_string()
    };
    if have_artifacts {
        pjrt_section(root, config, &mut results);
    }

    let out = Path::new("BENCH_offload.json");
    let prov = Provenance::collect("offload host dims K=4 T=512 W=64 C=64", 0, &note);
    write_json(out, "offload", false, &note, &prov, &results).expect("writing bench json");
    println!("\nwrote {}", out.display());
}
